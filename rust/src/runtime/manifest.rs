//! Typed view of `artifacts/manifest.json` (written by
//! `python/compile/aot.py`).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype '{other}'"),
        }
    }
}

/// One input or output tensor.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub meta: BTreeMap<String, Json>,
}

impl ArtifactSpec {
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key)?.as_usize()
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub preset: String,
    pub block: usize,
    pub scal_dim: usize,
}

fn parse_io(v: &Json) -> Result<IoSpec> {
    let name = v
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("io entry missing name"))?
        .to_string();
    let shape = v
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("io entry '{name}' missing shape"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim in '{name}'")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = DType::parse(v.get("dtype").and_then(Json::as_str).unwrap_or("f32"))?;
    Ok(IoSpec { name, shape, dtype })
}

impl Manifest {
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Manifest> {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {:?} (run `make artifacts`)", path.as_ref()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;
        let version = root
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing version"))?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let meta = root.get("meta").ok_or_else(|| anyhow!("manifest missing meta"))?;
        let preset = meta.get("preset").and_then(Json::as_str).unwrap_or("?").to_string();
        let block = meta.get("block").and_then(Json::as_usize).unwrap_or(1024);
        let scal_dim = meta.get("scal_dim").and_then(Json::as_usize).unwrap_or(8);

        let mut artifacts = BTreeMap::new();
        let arts = root
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?;
        for (name, entry) in arts {
            let file = entry
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact '{name}' missing file"))?
                .to_string();
            let inputs = entry
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact '{name}' missing inputs"))?
                .iter()
                .map(parse_io)
                .collect::<Result<Vec<_>>>()?;
            let outputs = entry
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact '{name}' missing outputs"))?
                .iter()
                .map(parse_io)
                .collect::<Result<Vec<_>>>()?;
            let meta = entry
                .get("meta")
                .and_then(Json::as_obj)
                .cloned()
                .unwrap_or_default();
            artifacts.insert(
                name.clone(),
                ArtifactSpec { name: name.clone(), file, inputs, outputs, meta },
            );
        }
        Ok(Manifest { artifacts, preset, block, scal_dim })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "meta": {"preset": "test", "block": 1024, "scal_dim": 8},
      "artifacts": {
        "mlp_grad": {
          "file": "mlp_grad.hlo.txt",
          "inputs": [
            {"name": "theta", "shape": [2048], "dtype": "f32"},
            {"name": "x", "shape": [16, 784], "dtype": "f32"},
            {"name": "y", "shape": [16], "dtype": "i32"}
          ],
          "outputs": [
            {"name": "u", "shape": [], "dtype": "f32"},
            {"name": "grad", "shape": [2048], "dtype": "f32"}
          ],
          "meta": {"n_params": 2000, "padded_n": 2048, "batch": 16}
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.preset, "test");
        assert_eq!(m.block, 1024);
        let a = &m.artifacts["mlp_grad"];
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[1].elements(), 16 * 784);
        assert_eq!(a.inputs[2].dtype, DType::I32);
        assert_eq!(a.outputs[0].elements(), 1); // scalar: empty shape
        assert_eq!(a.meta_usize("n_params"), Some(2000));
    }

    #[test]
    fn scalar_shape_has_one_element() {
        let io = IoSpec { name: "u".into(), shape: vec![], dtype: DType::F32 };
        assert_eq!(io.elements(), 1);
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"version":1,"meta":{},"artifacts":{"a":{}}}"#).is_err());
    }
}
