//! PJRT runtime: load and execute the AOT-compiled HLO artifacts.
//!
//! `make artifacts` (python, build-time only) lowers the L2/L1 stack to
//! `artifacts/*.hlo.txt` plus `manifest.json`; this module is the only
//! consumer. Interchange is HLO **text** — xla_extension 0.5.1 rejects
//! jax≥0.5 serialized protos (64-bit instruction ids), while the text
//! parser reassigns ids and round-trips cleanly.
//!
//! Flow: [`Engine::new`] → `PjRtClient::cpu()`; [`Engine::load`] →
//! `HloModuleProto::from_text_file` → `client.compile` (cached per
//! artifact name) → [`LoadedArtifact::run`] on the sampler hot path.

pub mod manifest;

// Default build: the in-crate PJRT stub (graceful "runtime unavailable"
// errors). With `xla-runtime` this import compiles out and the bare
// `xla::` paths below resolve to the real extern crate instead.
#[cfg(not(feature = "xla-runtime"))]
use crate::xla;

use anyhow::{anyhow, bail, Context, Result};
use manifest::{ArtifactSpec, DType, Manifest};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Argument to an artifact invocation.
pub enum Arg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl<'a> Arg<'a> {
    fn len(&self) -> usize {
        match self {
            Arg::F32(s) => s.len(),
            Arg::I32(s) => s.len(),
        }
    }

    fn dtype(&self) -> DType {
        match self {
            Arg::F32(_) => DType::F32,
            Arg::I32(_) => DType::I32,
        }
    }
}

/// A compiled artifact plus its manifest entry.
///
/// SAFETY of `Send + Sync`: `PjRtLoadedExecutable` wraps a PJRT CPU
/// executable; the PJRT C API guarantees `Execute` is thread-safe, and the
/// wrapper holds no interior mutability on the Rust side. Workers share
/// one compiled executable and call [`run`](Self::run) concurrently.
pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

unsafe impl Send for LoadedArtifact {}
unsafe impl Sync for LoadedArtifact {}

impl LoadedArtifact {
    /// Execute with shape/dtype validation against the manifest.
    /// Returns one `Vec<f32>` per output (i32 outputs are not used by any
    /// current artifact).
    pub fn run(&self, args: &[Arg<'_>]) -> Result<Vec<Vec<f32>>> {
        if args.len() != self.spec.inputs.len() {
            bail!(
                "artifact {}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                args.len()
            );
        }
        let mut literals = Vec::with_capacity(args.len());
        for (arg, io) in args.iter().zip(&self.spec.inputs) {
            if arg.len() != io.elements() {
                bail!(
                    "artifact {} input '{}': expected {} elements ({:?}), got {}",
                    self.spec.name,
                    io.name,
                    io.elements(),
                    io.shape,
                    arg.len()
                );
            }
            if arg.dtype() != io.dtype {
                bail!(
                    "artifact {} input '{}': dtype mismatch (manifest {:?})",
                    self.spec.name,
                    io.name,
                    io.dtype
                );
            }
            let dims: Vec<i64> = io.shape.iter().map(|&d| d as i64).collect();
            let lit = match arg {
                Arg::F32(s) => xla::Literal::vec1(s),
                Arg::I32(s) => xla::Literal::vec1(s),
            };
            let lit = if io.shape.len() == 1 {
                lit
            } else {
                lit.reshape(&dims).with_context(|| {
                    format!("reshaping input '{}' to {:?}", io.name, io.shape)
                })?
            };
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing artifact {}", self.spec.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = out.to_tuple().context("decomposing result tuple")?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "artifact {}: manifest promises {} outputs, module returned {}",
                self.spec.name,
                self.spec.outputs.len(),
                parts.len()
            );
        }
        let mut outs = Vec::with_capacity(parts.len());
        for (part, io) in parts.iter().zip(&self.spec.outputs) {
            let v: Vec<f32> = part
                .to_vec()
                .with_context(|| format!("reading output '{}'", io.name))?;
            if v.len() != io.elements() {
                bail!(
                    "artifact {} output '{}': expected {} elements, got {}",
                    self.spec.name,
                    io.name,
                    io.elements(),
                    v.len()
                );
            }
            outs.push(v);
        }
        Ok(outs)
    }
}

/// PJRT engine: client + manifest + compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<LoadedArtifact>>>,
}

// SAFETY: see LoadedArtifact. PjRtClient (CPU) is thread-safe per the
// PJRT C API contract; the cache is mutex-guarded.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Open the artifacts directory (reads `manifest.json`, creates the
    /// PJRT CPU client).
    pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Engine> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine { client, dir, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Locate the artifacts dir: explicit arg, `ECSGMCMC_ARTIFACTS`, or
    /// `<repo>/artifacts` relative to the crate manifest.
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("ECSGMCMC_ARTIFACTS") {
            return PathBuf::from(d);
        }
        let repo = Path::new(env!("CARGO_MANIFEST_DIR"));
        repo.join("artifacts")
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile-and-cache) an artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<Arc<LoadedArtifact>> {
        if let Some(hit) = self.cache.lock().unwrap().get(name) {
            return Ok(hit.clone());
        }
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling artifact '{name}': {e:?}"))?;
        let loaded = Arc::new(LoadedArtifact { spec, exe });
        self.cache.lock().unwrap().insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Pre-compile several artifacts (worker warm-up before timing starts).
    pub fn preload(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.load(n)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine integration tests live in rust/tests/test_xla_roundtrip.rs
    // (they need built artifacts); here we only cover Arg plumbing.

    #[test]
    fn arg_reports_len_and_dtype() {
        let f = [1.0f32, 2.0];
        let i = [1i32];
        assert_eq!(Arg::F32(&f).len(), 2);
        assert_eq!(Arg::I32(&i).len(), 1);
        assert_eq!(Arg::F32(&f).dtype(), DType::F32);
        assert_eq!(Arg::I32(&i).dtype(), DType::I32);
    }

    #[test]
    fn default_dir_points_into_repo() {
        std::env::remove_var("ECSGMCMC_ARTIFACTS");
        let d = Engine::default_dir();
        assert!(d.ends_with("artifacts"));
    }
}
