//! Exact Hamiltonian Monte Carlo with Metropolis–Hastings correction
//! (Duane et al. 1987, Neal 2010).
//!
//! Used as the gold-standard sampler on the analytic toys: it has no
//! discretization bias, so the diagnostics suite can compare SGHMC / EC
//! moments against both the analytic truth and HMC's empirical ones.
//! Requires exact (full-data) potential and gradient — the toy potentials
//! provide both.

use crate::math::rng::Pcg64;
use crate::math::vecops;
use crate::potentials::Potential;

pub struct HmcSampler {
    pub eps: f64,
    pub leapfrog_steps: usize,
    pub accepted: u64,
    pub proposed: u64,
}

impl HmcSampler {
    pub fn new(eps: f64, leapfrog_steps: usize) -> Self {
        Self { eps, leapfrog_steps, accepted: 0, proposed: 0 }
    }

    pub fn acceptance_rate(&self) -> f64 {
        if self.proposed == 0 {
            return 0.0;
        }
        self.accepted as f64 / self.proposed as f64
    }

    /// One full HMC transition (leapfrog trajectory + MH accept/reject).
    /// Returns the (possibly unchanged) potential value at the new state.
    pub fn transition(
        &mut self,
        potential: &dyn Potential,
        theta: &mut [f32],
        rng: &mut Pcg64,
    ) -> f64 {
        let n = theta.len();
        let mut p = vec![0.0f32; n];
        rng.fill_normal(&mut p);

        let mut grad = vec![0.0f32; n];
        let u0 = potential.full_grad(theta, &mut grad);
        let k0 = 0.5 * vecops::norm_sq(&p);

        let mut prop = theta.to_vec();
        let eps = self.eps as f32;

        // Leapfrog: half-kick, L-1 (drift, kick), drift, half-kick.
        vecops::axpy(-0.5 * eps, &grad, &mut p);
        for step in 0..self.leapfrog_steps {
            vecops::axpy(eps, &p, &mut prop);
            let _ = potential.full_grad(&prop, &mut grad);
            let kick = if step + 1 == self.leapfrog_steps { -0.5 * eps } else { -eps };
            vecops::axpy(kick, &grad, &mut p);
        }

        let u1 = potential.full_grad(&prop, &mut grad);
        let k1 = 0.5 * vecops::norm_sq(&p);

        self.proposed += 1;
        let log_accept = (u0 + k0) - (u1 + k1);
        if log_accept >= 0.0 || rng.next_f64() < log_accept.exp() {
            theta.copy_from_slice(&prop);
            self.accepted += 1;
            u1
        } else {
            u0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::potentials::gaussian::GaussianPotential;

    #[test]
    fn samples_fig1_gaussian_exactly() {
        let pot = GaussianPotential::fig1();
        let mut hmc = HmcSampler::new(0.25, 8);
        let mut rng = Pcg64::seeded(21);
        let mut theta = vec![2.0f32, 2.0];
        let mut samples: Vec<Vec<f64>> = Vec::new();
        for t in 0..30_000 {
            hmc.transition(&pot, &mut theta, &mut rng);
            if t >= 2_000 {
                samples.push(theta.iter().map(|&x| x as f64).collect());
            }
        }
        assert!(hmc.acceptance_rate() > 0.8, "accept={}", hmc.acceptance_rate());
        let cov = crate::math::stats::covariance(&samples);
        // True covariance [[1, .6], [.6, .8]].
        assert!((cov[0] - 1.0).abs() < 0.08, "cov00={}", cov[0]);
        assert!((cov[1] - 0.6).abs() < 0.08, "cov01={}", cov[1]);
        assert!((cov[3] - 0.8).abs() < 0.08, "cov11={}", cov[3]);
        let mx = crate::math::stats::mean(&samples.iter().map(|s| s[0]).collect::<Vec<_>>());
        assert!(mx.abs() < 0.06, "mean={mx}");
    }

    #[test]
    fn energy_error_shrinks_with_step_size() {
        // Acceptance should improve as eps decreases (symplectic integrator).
        let pot = GaussianPotential::fig1();
        let mut rng = Pcg64::seeded(22);
        let mut rates = Vec::new();
        for eps in [0.9, 0.3, 0.1] {
            let mut hmc = HmcSampler::new(eps, 8);
            let mut theta = vec![0.5f32, -0.5];
            for _ in 0..2_000 {
                hmc.transition(&pot, &mut theta, &mut rng);
            }
            rates.push(hmc.acceptance_rate());
        }
        assert!(rates[0] <= rates[1] + 0.05, "{rates:?}");
        assert!(rates[1] <= rates[2] + 0.05, "{rates:?}");
        assert!(rates[2] > 0.95, "{rates:?}");
    }
}
