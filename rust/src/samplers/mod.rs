//! Stochastic-gradient MCMC samplers (Layer-3 native implementations).
//!
//! The paper's dynamics, in the discretized forms it writes down:
//!
//! * [`sghmc`] — stochastic gradient Hamiltonian Monte Carlo, Eq. (4);
//! * [`sgld`] — stochastic gradient Langevin dynamics (Welling & Teh),
//!   which the paper notes also admits elastic coupling;
//! * [`hmc`] — exact HMC with Metropolis–Hastings correction, the
//!   gold-standard baseline for the analytic toys.
//!
//! Elastic coupling (Eq. 6) enters through the optional `coupling`
//! argument of the step functions — the same code path serves standalone
//! SGHMC (`coupling = None`) and EC workers, which is what makes the
//! α = 0 ⇒ independent-chains decomposition of Eq. (5) testable bit-for-bit
//! (see `rust/tests/test_ec_invariants.rs`).

pub mod hmc;
pub mod sgld;
pub mod sghmc;

use crate::math::rng::Pcg64;

/// Which noise convention the EC dynamics use.
///
/// The paper's Eq. (6) writes the worker/center noise as N(0, 2ε²(V+C)) /
/// N(0, 2ε²C) — *second order* in ε, consistent with V being the
/// variance of the minibatch gradient noise that the ε∇Ũ term injects by
/// itself (Chen et al. 2014 convention). On targets with **exact**
/// gradients (the analytic toys) that leaves the dynamics under-noised
/// and the stationary variance collapses by a factor of O(ε). We therefore
/// support both conventions:
///
/// * [`NoiseMode::FirstOrder`] (default) — friction-matched first-order
///   noise N(0, 2εV) as in Eq. (4), which yields the exact stationary
///   distribution regardless of gradient-noise magnitude;
/// * [`NoiseMode::PaperEq6`] — the literal Eq. (6) scales, appropriate
///   when minibatch noise dominates (the NN experiments).
///
/// The discrepancy and this resolution are documented in DESIGN.md §5.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NoiseMode {
    #[default]
    FirstOrder,
    PaperEq6,
}

/// Hyperparameters shared by the SG-MCMC family.
///
/// The paper's Fig. 1 setting is `eps = 1e-2`, `M = I`, `C = V = I`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SghmcParams {
    /// Step size ε.
    pub eps: f64,
    /// Isotropic inverse mass M⁻¹.
    pub mass_inv: f64,
    /// Gradient-noise / friction matrix V (isotropic scalar).
    pub friction: f64,
    /// Center-noise matrix C (isotropic scalar), Eq. (6).
    pub center_friction: f64,
    /// Variance of the injected noise; the paper uses V here too.
    pub noise_var: f64,
    /// Noise convention for the EC dynamics (see [`NoiseMode`]).
    pub noise_mode: NoiseMode,
}

impl Default for SghmcParams {
    fn default() -> Self {
        Self {
            eps: 1e-2,
            mass_inv: 1.0,
            friction: 1.0,
            center_friction: 1.0,
            noise_var: 1.0,
            noise_mode: NoiseMode::FirstOrder,
        }
    }
}

impl SghmcParams {
    /// Noise std-dev for plain SGHMC, Eq. (4): N(0, 2 ε V).
    pub fn sghmc_noise_scale(&self) -> f64 {
        (2.0 * self.eps * self.noise_var).sqrt()
    }

    /// Noise std-dev for an EC worker (Eq. 6; see [`NoiseMode`]).
    pub fn ec_worker_noise_scale(&self) -> f64 {
        match self.noise_mode {
            NoiseMode::FirstOrder => (2.0 * self.eps * self.noise_var).sqrt(),
            NoiseMode::PaperEq6 => {
                (2.0 * self.eps * self.eps * (self.noise_var + self.center_friction)).sqrt()
            }
        }
    }

    /// Noise std-dev for the center variable (Eq. 6; see [`NoiseMode`]).
    pub fn center_noise_scale(&self) -> f64 {
        match self.noise_mode {
            NoiseMode::FirstOrder => (2.0 * self.eps * self.center_friction).sqrt(),
            NoiseMode::PaperEq6 => {
                (2.0 * self.eps * self.eps * self.center_friction).sqrt()
            }
        }
    }

    /// Noise std-dev for SGLD: N(0, 2 ε).
    pub fn sgld_noise_scale(&self) -> f64 {
        (2.0 * self.eps).sqrt()
    }
}

/// Position + momentum of one chain (flat f32, padded length allowed).
#[derive(Debug, Clone, PartialEq)]
pub struct ChainState {
    pub theta: Vec<f32>,
    pub p: Vec<f32>,
}

impl ChainState {
    /// Zero-initialized state of dimension `n`.
    pub fn zeros(n: usize) -> Self {
        Self { theta: vec![0.0; n], p: vec![0.0; n] }
    }

    /// Gaussian-initialized position (scale σ), zero momentum.
    pub fn init_gaussian(n: usize, sigma: f32, rng: &mut Pcg64) -> Self {
        let mut theta = vec![0.0f32; n];
        rng.fill_normal(&mut theta);
        for t in theta.iter_mut() {
            *t *= sigma;
        }
        Self { theta, p: vec![0.0; n] }
    }

    /// Start all chains from the same point (the paper's Fig. 1 setup).
    pub fn from_theta(theta: Vec<f32>) -> Self {
        let n = theta.len();
        Self { theta, p: vec![0.0; n] }
    }

    pub fn dim(&self) -> usize {
        self.theta.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noise_scales_match_paper_formulas() {
        let p = SghmcParams {
            eps: 0.01,
            mass_inv: 1.0,
            friction: 2.0,
            center_friction: 3.0,
            noise_var: 2.0,
            noise_mode: NoiseMode::PaperEq6,
        };
        assert!((p.sghmc_noise_scale() - (2.0 * 0.01 * 2.0f64).sqrt()).abs() < 1e-15);
        assert!(
            (p.ec_worker_noise_scale() - (2.0 * 0.01f64 * 0.01 * 5.0).sqrt()).abs() < 1e-15
        );
        assert!((p.center_noise_scale() - (2.0 * 0.01f64 * 0.01 * 3.0).sqrt()).abs() < 1e-15);
        assert!((p.sgld_noise_scale() - 0.02f64.sqrt()).abs() < 1e-15);
    }

    #[test]
    fn first_order_mode_matches_eq4_scale() {
        let p = SghmcParams { eps: 0.01, noise_var: 2.0, ..Default::default() };
        assert_eq!(p.noise_mode, NoiseMode::FirstOrder);
        assert!((p.ec_worker_noise_scale() - p.sghmc_noise_scale()).abs() < 1e-15);
        assert!((p.center_noise_scale() - (2.0 * 0.01f64).sqrt()).abs() < 1e-15);
    }

    #[test]
    fn chain_state_inits() {
        let mut rng = Pcg64::seeded(0);
        let z = ChainState::zeros(4);
        assert_eq!(z.theta, vec![0.0; 4]);
        let g = ChainState::init_gaussian(1000, 2.0, &mut rng);
        let var: f64 =
            g.theta.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / 1000.0;
        assert!((var - 4.0).abs() < 0.6, "var={var}");
        assert_eq!(g.p, vec![0.0; 1000]);
    }
}
