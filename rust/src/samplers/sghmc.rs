//! SGHMC stepper: the discretized dynamics of paper Eqs. (4) and (6).
//!
//! One struct serves three roles:
//!
//! * plain SGHMC (Eq. 4) with `coupling = None`;
//! * an elastically-coupled worker (Eq. 6 rows 1+3) with
//!   `coupling = Some((center, alpha))`;
//! * the center variable itself (Eq. 6 rows 2+4) via [`center_step`] —
//!   structurally the same update with the worker-mean as the attractor
//!   and C in place of V.
//!
//! All updates are simultaneous-form exactly as the paper writes them:
//! both rows read time-t state. Buffers are preallocated; the hot loop is
//! allocation-free.

use super::{ChainState, SghmcParams};
use crate::math::rng::Pcg64;

/// Reusable stepper holding the noise buffer.
pub struct SghmcStepper {
    pub params: SghmcParams,
    noise: Vec<f32>,
    /// Zero the noise tail beyond `live_dim` (padding hygiene for
    /// artifact-backed potentials whose vectors are block-padded).
    live_dim: usize,
}

impl SghmcStepper {
    pub fn new(params: SghmcParams, dim: usize) -> Self {
        Self { params, noise: vec![0.0; dim], live_dim: dim }
    }

    /// Restrict noise injection to the first `live` coordinates.
    pub fn with_live_dim(mut self, live: usize) -> Self {
        assert!(live <= self.noise.len());
        self.live_dim = live;
        self
    }

    /// Advance one SGHMC / EC-worker step.
    ///
    /// * `grad` — ∇Ũ(θ_t), computed by the caller *before* this call;
    /// * `coupling` — `Some((center, alpha))` adds the elastic force of
    ///   Eq. (6); the noise scale switches to the Eq. (6) form as well.
    pub fn step(
        &mut self,
        state: &mut ChainState,
        grad: &[f32],
        coupling: Option<(&[f32], f64)>,
        rng: &mut Pcg64,
    ) {
        let n = state.theta.len();
        debug_assert_eq!(grad.len(), n);
        debug_assert_eq!(self.noise.len(), n);
        let eps = self.params.eps as f32;
        let minv = self.params.mass_inv as f32;
        let fric = self.params.friction as f32;
        let nscale = match coupling {
            None => self.params.sghmc_noise_scale() as f32,
            Some(_) => self.params.ec_worker_noise_scale() as f32,
        };

        rng.fill_normal(&mut self.noise[..self.live_dim]);
        if self.live_dim < n {
            self.noise[self.live_dim..].fill(0.0);
        }

        match coupling {
            None => {
                for i in 0..n {
                    let theta = state.theta[i];
                    let p = state.p[i];
                    // Eq. (4), simultaneous form.
                    state.theta[i] = theta + eps * minv * p;
                    state.p[i] =
                        p - eps * grad[i] - eps * fric * minv * p + nscale * self.noise[i];
                }
            }
            Some((center, alpha)) => {
                debug_assert_eq!(center.len(), n);
                let alpha = alpha as f32;
                for i in 0..n {
                    let theta = state.theta[i];
                    let p = state.p[i];
                    // Eq. (6) rows 1 + 3.
                    state.theta[i] = theta + eps * minv * p;
                    state.p[i] = p - eps * grad[i] - eps * fric * minv * p
                        - eps * alpha * (theta - center[i])
                        + nscale * self.noise[i];
                }
            }
        }
    }

    /// Advance B chains one step each on a single thread (DESIGN.md §9).
    ///
    /// `grads` is the stacked output of one
    /// [`Potential::stoch_grad_batch`](crate::potentials::Potential::stoch_grad_batch)
    /// evaluation (B × dim). The one shared noise buffer is swept once
    /// per chain, each chain drawing from its own stream — so every
    /// chain's trajectory is bit-identical to unbatched stepping — and
    /// `couplings` pairs each chain with its own (possibly stale) view
    /// of the shared center.
    pub fn step_batch(
        &mut self,
        states: &mut [&mut ChainState],
        grads: &[f32],
        couplings: Option<(&[&[f32]], f64)>,
        rngs: &mut [&mut Pcg64],
    ) {
        let b = states.len();
        let dim = self.noise.len();
        debug_assert_eq!(grads.len(), b * dim);
        debug_assert_eq!(rngs.len(), b);
        if let Some((centers, _)) = couplings {
            debug_assert_eq!(centers.len(), b);
        }
        for i in 0..b {
            let grad = &grads[i * dim..(i + 1) * dim];
            let coupling = couplings.map(|(centers, alpha)| (centers[i], alpha));
            self.step(states[i], grad, coupling, rngs[i]);
        }
    }
}

/// Center-variable stepper (Eq. 6 rows 2+4). `state.theta` is c,
/// `state.p` is r; `theta_mean` is (1/K) Σᵢ θᵢ.
pub struct CenterStepper {
    pub params: SghmcParams,
    pub alpha: f64,
    noise: Vec<f32>,
    live_dim: usize,
}

impl CenterStepper {
    pub fn new(params: SghmcParams, alpha: f64, dim: usize) -> Self {
        Self { params, alpha, noise: vec![0.0; dim], live_dim: dim }
    }

    pub fn with_live_dim(mut self, live: usize) -> Self {
        assert!(live <= self.noise.len());
        self.live_dim = live;
        self
    }

    pub fn step(&mut self, state: &mut ChainState, theta_mean: &[f32], rng: &mut Pcg64) {
        let n = state.theta.len();
        self.step_range(state, theta_mean, 0..n, rng);
    }

    /// Advance only the coordinates in `range` (a contiguous θ shard).
    ///
    /// Sharded center stepping for NN-sized parameters: the server can
    /// step and publish each shard independently, each shard drawing from
    /// its own RNG stream. `step_range(0..dim)` is bit-identical to
    /// [`step`](Self::step), which keeps the single-shard deterministic
    /// configuration byte-compatible with the pre-sharding coordinator.
    pub fn step_range(
        &mut self,
        state: &mut ChainState,
        theta_mean: &[f32],
        range: std::ops::Range<usize>,
        rng: &mut Pcg64,
    ) {
        let n = state.theta.len();
        debug_assert_eq!(theta_mean.len(), n);
        debug_assert!(range.end <= n);
        let eps = self.params.eps as f32;
        let minv = self.params.mass_inv as f32;
        let cfric = self.params.center_friction as f32;
        let alpha = self.alpha as f32;
        let nscale = self.params.center_noise_scale() as f32;

        // Noise only on the live slice of this range; padding stays zero.
        let live_hi = range.end.min(self.live_dim);
        let live_lo = range.start.min(live_hi);
        rng.fill_normal(&mut self.noise[live_lo..live_hi]);
        if live_hi < range.end {
            self.noise[live_hi..range.end].fill(0.0);
        }
        for i in range {
            let c = state.theta[i];
            let r = state.p[i];
            state.theta[i] = c + eps * minv * r;
            state.p[i] = r - eps * cfric * minv * r - eps * alpha * (c - theta_mean[i])
                + nscale * self.noise[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::vecops;

    fn params() -> SghmcParams {
        SghmcParams { eps: 1e-2, ..Default::default() }
    }

    /// Hand-computed single step against the Eq. (4) formulas.
    #[test]
    fn single_step_matches_formula() {
        let mut p = params();
        p.noise_var = 0.0; // deterministic
        let mut stepper = SghmcStepper::new(p, 2);
        let mut state = ChainState { theta: vec![1.0, -2.0], p: vec![0.5, 0.25] };
        let grad = [10.0f32, -4.0];
        let mut rng = Pcg64::seeded(0);
        stepper.step(&mut state, &grad, None, &mut rng);
        let eps = 0.01f32;
        // theta' = theta + eps * p
        assert!((state.theta[0] - (1.0 + eps * 0.5)).abs() < 1e-7);
        assert!((state.theta[1] - (-2.0 + eps * 0.25)).abs() < 1e-7);
        // p' = p - eps*grad - eps*V*p  (noise off)
        assert!((state.p[0] - (0.5 - eps * 10.0 - eps * 0.5)).abs() < 1e-7);
        assert!((state.p[1] - (0.25 + eps * 4.0 - eps * 0.25)).abs() < 1e-7);
    }

    #[test]
    fn coupling_pulls_toward_center() {
        let mut p = params();
        p.noise_var = 0.0;
        p.center_friction = 0.0;
        let mut stepper = SghmcStepper::new(p, 1);
        let center = [0.0f32];
        let grad = [0.0f32];
        let mut rng = Pcg64::seeded(1);
        let mut state = ChainState { theta: vec![5.0], p: vec![0.0] };
        stepper.step(&mut state, &grad, Some((&center, 10.0)), &mut rng);
        // Momentum must have moved toward the center (negative).
        assert!(state.p[0] < 0.0, "p={}", state.p[0]);
        assert!((state.p[0] - (-0.01 * 10.0 * 5.0)).abs() < 1e-6);
    }

    #[test]
    fn zero_alpha_coupling_equals_plain_step_with_ec_noise_off() {
        let mut prm = params();
        prm.noise_var = 0.0;
        prm.center_friction = 0.0; // makes both noise scales zero
        let grad = [3.0f32, -1.0];
        let center = [100.0f32, -50.0];
        let mut a = ChainState { theta: vec![1.0, 2.0], p: vec![0.1, -0.2] };
        let mut b = a.clone();
        let mut rng1 = Pcg64::seeded(2);
        let mut rng2 = Pcg64::seeded(2);
        SghmcStepper::new(prm, 2).step(&mut a, &grad, None, &mut rng1);
        SghmcStepper::new(prm, 2).step(&mut b, &grad, Some((&center, 0.0)), &mut rng2);
        assert_eq!(a, b);
    }

    #[test]
    fn live_dim_zeroes_padding_noise() {
        let prm = params();
        let mut stepper = SghmcStepper::new(prm, 8).with_live_dim(3);
        let mut state = ChainState::zeros(8);
        let grad = [0.0f32; 8];
        let mut rng = Pcg64::seeded(3);
        for _ in 0..10 {
            stepper.step(&mut state, &grad, None, &mut rng);
        }
        // Tail coordinates received no noise and no gradient: still zero.
        assert_eq!(&state.theta[3..], &[0.0; 5]);
        assert_eq!(&state.p[3..], &[0.0; 5]);
        // Live coordinates moved.
        assert!(vecops::norm_sq(&state.theta[..3]) > 0.0);
    }

    #[test]
    fn center_stepper_tracks_mean() {
        let prm = SghmcParams { eps: 0.05, center_friction: 0.0, ..params() };
        let mut cs = CenterStepper::new(prm, 4.0, 1);
        let mut state = ChainState::zeros(1);
        let mean = [2.0f32];
        let mut rng = Pcg64::seeded(4);
        for _ in 0..4000 {
            cs.step(&mut state, &mean, &mut rng);
        }
        // Harmonic oscillator around the mean with no damping... add tiny
        // friction via params to settle instead:
        let prm2 = SghmcParams { eps: 0.05, center_friction: 1.0, ..params() };
        let mut cs2 = CenterStepper::new(prm2, 4.0, 1);
        let mut s2 = ChainState::zeros(1);
        let mut acc = 0.0f64;
        let mut count = 0usize;
        for t in 0..8000 {
            cs2.step(&mut s2, &mean, &mut rng);
            if t >= 2000 {
                acc += s2.theta[0] as f64;
                count += 1;
            }
        }
        // The center is an OU-like process around the worker mean: its
        // time-average must settle at 2 (first-order noise keeps finite
        // jitter, so average rather than point-check).
        let avg = acc / count as f64;
        assert!((avg - 2.0).abs() < 0.25, "avg c={avg}");
        let _ = state;
    }

    #[test]
    fn center_step_range_shards_compose_deterministically() {
        // Stepping shard ranges with per-shard streams is deterministic,
        // covers every live coordinate, and never touches padding.
        let prm = SghmcParams { eps: 0.05, ..params() };
        // Worker snapshots are zero-padded, so the mean is too.
        let mut mean = vec![1.0f32; 8];
        mean[6..].fill(0.0);
        let run = || {
            let mut cs = CenterStepper::new(prm, 2.0, 8).with_live_dim(6);
            let mut st = ChainState::zeros(8);
            let mut rngs = [Pcg64::new(9, 1), Pcg64::new(9, 2)];
            for _ in 0..50 {
                cs.step_range(&mut st, &mean, 0..4, &mut rngs[0]);
                cs.step_range(&mut st, &mean, 4..8, &mut rngs[1]);
            }
            st
        };
        let a = run();
        let b = run();
        assert_eq!(a, b);
        assert!(vecops::norm_sq(&a.theta[..6]) > 0.0);
        assert_eq!(&a.theta[6..], &[0.0, 0.0]);
        assert_eq!(&a.p[6..], &[0.0, 0.0]);
    }

    #[test]
    fn center_step_range_full_range_equals_step() {
        let prm = SghmcParams { eps: 0.05, ..params() };
        let mean = vec![0.5f32; 4];
        let mut a = CenterStepper::new(prm, 1.5, 4).with_live_dim(3);
        let mut b = CenterStepper::new(prm, 1.5, 4).with_live_dim(3);
        let mut sa = ChainState { theta: vec![1.0, -1.0, 0.5, 0.0], p: vec![0.0; 4] };
        let mut sb = sa.clone();
        let mut ra = Pcg64::seeded(21);
        let mut rb = Pcg64::seeded(21);
        for _ in 0..25 {
            a.step(&mut sa, &mean, &mut ra);
            b.step_range(&mut sb, &mean, 0..4, &mut rb);
        }
        assert_eq!(sa, sb);
    }

    /// Stationary check: sampling a 1-D standard normal via exact gradients.
    #[test]
    fn samples_standard_normal() {
        let prm = SghmcParams { eps: 0.05, ..Default::default() };
        let mut stepper = SghmcStepper::new(prm, 1);
        let mut state = ChainState { theta: vec![3.0], p: vec![0.0] };
        let mut rng = Pcg64::seeded(5);
        let mut sum = 0.0f64;
        let mut sum_sq = 0.0f64;
        let total = 200_000;
        let burn = 2_000;
        let mut grad = [0.0f32];
        for t in 0..total {
            grad[0] = state.theta[0]; // dU/dtheta for U = theta^2/2
            stepper.step(&mut state, &grad, None, &mut rng);
            if t >= burn {
                let x = state.theta[0] as f64;
                sum += x;
                sum_sq += x * x;
            }
        }
        let n = (total - burn) as f64;
        let mean = sum / n;
        let var = sum_sq / n - mean * mean;
        assert!(mean.abs() < 0.1, "mean={mean}");
        // Discretization inflates variance by O(eps); allow 15%.
        assert!((var - 1.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn step_batch_matches_per_chain_steps_bitwise() {
        // The batched stepper is a packing of independent per-chain
        // steps: same streams, same noise draws, bit-identical states.
        let prm = params();
        let mut a1 = ChainState { theta: vec![1.0, -2.0], p: vec![0.5, 0.25] };
        let mut a2 = ChainState { theta: vec![0.3, 0.7], p: vec![-0.1, 0.2] };
        let mut b1 = a1.clone();
        let mut b2 = a2.clone();
        let grads = [10.0f32, -4.0, 1.0, 2.0];
        let center1 = [0.0f32, 0.0];
        let center2 = [1.0f32, -1.0];
        let mut r1 = Pcg64::new(3, 1000);
        let mut r2 = Pcg64::new(3, 1001);
        let mut r1b = r1.clone();
        let mut r2b = r2.clone();
        let mut stepper = SghmcStepper::new(prm, 2);
        stepper.step(&mut a1, &grads[..2], Some((&center1, 2.0)), &mut r1);
        stepper.step(&mut a2, &grads[2..], Some((&center2, 2.0)), &mut r2);
        let mut batch_stepper = SghmcStepper::new(prm, 2);
        {
            let mut states: Vec<&mut ChainState> = vec![&mut b1, &mut b2];
            let centers: Vec<&[f32]> = vec![&center1, &center2];
            let mut rngs: Vec<&mut Pcg64> = vec![&mut r1b, &mut r2b];
            batch_stepper.step_batch(&mut states, &grads, Some((&centers, 2.0)), &mut rngs);
        }
        assert_eq!(a1, b1);
        assert_eq!(a2, b2);
        assert_eq!(r1.snapshot(), r1b.snapshot());
        assert_eq!(r2.snapshot(), r2b.snapshot());
    }
}
