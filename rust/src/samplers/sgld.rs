//! Stochastic gradient Langevin dynamics (Welling & Teh, 2011), plus the
//! elastically-coupled variant the paper sketches in Sec. 3 ("we can thus
//! derive similar asynchronous samplers for any SGMCMC variant including
//! first order stochastic Langevin dynamics").
//!
//! Update: θ_{t+1} = θ_t − ε ∇Ũ(θ_t) [− ε α (θ_t − c̃_t)] + N(0, 2ε).
//!
//! The coupled form is exactly what Sec. 5 predicts: EC-SGLD's
//! deterministic limit recovers plain EASGD (no momentum discrepancy).

use super::{ChainState, SghmcParams};
use crate::math::rng::Pcg64;

pub struct SgldStepper {
    pub params: SghmcParams,
    noise: Vec<f32>,
    live_dim: usize,
}

impl SgldStepper {
    pub fn new(params: SghmcParams, dim: usize) -> Self {
        Self { params, noise: vec![0.0; dim], live_dim: dim }
    }

    pub fn with_live_dim(mut self, live: usize) -> Self {
        assert!(live <= self.noise.len());
        self.live_dim = live;
        self
    }

    /// One SGLD / EC-SGLD step (momentum in `state.p` is ignored).
    pub fn step(
        &mut self,
        state: &mut ChainState,
        grad: &[f32],
        coupling: Option<(&[f32], f64)>,
        rng: &mut Pcg64,
    ) {
        let n = state.theta.len();
        debug_assert_eq!(grad.len(), n);
        let eps = self.params.eps as f32;
        let nscale = self.params.sgld_noise_scale() as f32;
        rng.fill_normal(&mut self.noise[..self.live_dim]);
        if self.live_dim < n {
            self.noise[self.live_dim..].fill(0.0);
        }
        match coupling {
            None => {
                for i in 0..n {
                    state.theta[i] += -eps * grad[i] + nscale * self.noise[i];
                }
            }
            Some((center, alpha)) => {
                debug_assert_eq!(center.len(), n);
                let alpha = alpha as f32;
                for i in 0..n {
                    let theta = state.theta[i];
                    state.theta[i] =
                        theta - eps * grad[i] - eps * alpha * (theta - center[i])
                            + nscale * self.noise[i];
                }
            }
        }
    }

    /// Batched sibling of [`SgldStepper::step`] — see
    /// [`SghmcStepper::step_batch`](super::sghmc::SghmcStepper::step_batch)
    /// for the contract (stacked grads, per-chain streams and views).
    pub fn step_batch(
        &mut self,
        states: &mut [&mut ChainState],
        grads: &[f32],
        couplings: Option<(&[&[f32]], f64)>,
        rngs: &mut [&mut Pcg64],
    ) {
        let b = states.len();
        let dim = self.noise.len();
        debug_assert_eq!(grads.len(), b * dim);
        debug_assert_eq!(rngs.len(), b);
        for i in 0..b {
            let grad = &grads[i * dim..(i + 1) * dim];
            let coupling = couplings.map(|(centers, alpha)| (centers[i], alpha));
            self.step(states[i], grad, coupling, rngs[i]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_standard_normal() {
        let prm = SghmcParams { eps: 0.01, ..Default::default() };
        let mut stepper = SgldStepper::new(prm, 1);
        let mut state = ChainState { theta: vec![4.0], p: vec![] };
        // ChainState::p unused by SGLD; keep dims consistent anyway.
        state.p = vec![0.0];
        let mut rng = Pcg64::seeded(11);
        let (mut sum, mut sum_sq) = (0.0f64, 0.0f64);
        let total = 400_000;
        let burn = 5_000;
        let mut grad = [0.0f32];
        for t in 0..total {
            grad[0] = state.theta[0];
            stepper.step(&mut state, &grad, None, &mut rng);
            if t >= burn {
                let x = state.theta[0] as f64;
                sum += x;
                sum_sq += x * x;
            }
        }
        let n = (total - burn) as f64;
        let mean = sum / n;
        let var = sum_sq / n - mean * mean;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn coupling_contracts_toward_center_when_strong() {
        let prm = SghmcParams { eps: 0.01, ..Default::default() };
        let mut stepper = SgldStepper::new(prm, 1);
        let mut rng = Pcg64::seeded(12);
        let center = [10.0f32];
        let mut state = ChainState { theta: vec![0.0], p: vec![0.0] };
        let grad = [0.0f32];
        for _ in 0..5_000 {
            stepper.step(&mut state, &grad, Some((&center, 50.0)), &mut rng);
        }
        assert!((state.theta[0] - 10.0).abs() < 1.0, "theta={}", state.theta[0]);
    }

    #[test]
    fn deterministic_when_noise_removed() {
        // eps contributes noise sqrt(2 eps); emulate the deterministic limit
        // by zeroing the generator output region: use live_dim = 0.
        let prm = SghmcParams { eps: 0.1, ..Default::default() };
        let mut stepper = SgldStepper::new(prm, 2).with_live_dim(0);
        let mut state = ChainState { theta: vec![1.0, -1.0], p: vec![0.0, 0.0] };
        let grad = [2.0f32, -2.0];
        let mut rng = Pcg64::seeded(13);
        stepper.step(&mut state, &grad, None, &mut rng);
        assert_eq!(state.theta, vec![1.0 - 0.1 * 2.0, -1.0 + 0.1 * 2.0]);
    }
}
