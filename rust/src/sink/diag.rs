//! Online convergence diagnostics as a sink: running moments, split-R̂
//! and ESS computed *while sampling*, without retaining θ.
//!
//! The paper's headline claim — elastic coupling "significantly speeds
//! up exploration" — is a convergence-rate statement, so waiting for the
//! run to finish (and for the full trace to fit in RAM) to check it is
//! backwards. This sink folds every offered sample into bounded state:
//!
//! * pooled mean/covariance over the first [`MAX_TRACK`] coordinates via
//!   the multivariate Welford accumulator (`math::stats::CovWelford`) —
//!   O(track²) memory, matches the post-hoc `diagnostics::moments` up to
//!   floating-point rounding;
//! * per-(chain, coordinate) scalar chains with batch-means compression:
//!   draws are stored exactly until [`BATCH_CAP`], then adjacent pairs
//!   collapse into batch means and the batch size doubles — memory stays
//!   O(BATCH_CAP) per scalar chain for any run length. While the batch
//!   size is still 1 (runs up to `BATCH_CAP · thin` steps per chain),
//!   the end-of-run split-R̂ and ESS are *identical* to the post-hoc
//!   `diagnostics::{rhat, ess}` over the whole trace; past it they
//!   degrade gracefully into standard batch-means estimates.
//!
//! Frames push under a shared mutex; per-chain order is preserved (each
//! chain is single-threaded), pooled moments accumulate in arrival
//! order, so their last few floating-point digits can vary across
//! thread schedules — the estimators themselves are order-exact.

use super::{Frame, SampleSink};
use crate::diagnostics::{ess, rhat};
use crate::math::stats::CovWelford;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// Coordinates tracked for scalar-chain diagnostics (and pooled cov).
/// NN-sized θ gets its leading coordinates tracked, not all of them.
pub const MAX_TRACK: usize = 8;

/// Stored values per (chain, coordinate) before batch-means collapse.
/// Even: pairs collapse exactly.
pub const BATCH_CAP: usize = 8192;

/// Scalar chain with bounded storage (exact draws, then doubling batch
/// means).
#[derive(Debug, Clone, Default)]
struct ScalarChain {
    /// Current batch size; 1 until the first collapse.
    batch: usize,
    /// Completed batch means (raw draws while `batch == 1`).
    values: Vec<f64>,
    acc: f64,
    acc_n: usize,
    n: u64,
}

impl ScalarChain {
    fn push(&mut self, x: f64) {
        if self.batch == 0 {
            self.batch = 1;
        }
        self.n += 1;
        self.acc += x;
        self.acc_n += 1;
        if self.acc_n == self.batch {
            self.values.push(self.acc / self.batch as f64);
            self.acc = 0.0;
            self.acc_n = 0;
            if self.values.len() == BATCH_CAP {
                let collapsed: Vec<f64> =
                    self.values.chunks(2).map(|p| (p[0] + p[1]) / 2.0).collect();
                self.values = collapsed;
                self.batch *= 2;
            }
        }
    }
}

/// Shared accumulator every frame of a run pushes into.
#[derive(Debug, Default)]
pub struct OnlineDiag {
    /// Tracked coordinates, fixed by the first sample: min(dim, MAX_TRACK).
    track: usize,
    /// Chain id → per-coordinate scalar chains.
    chains: BTreeMap<usize, Vec<ScalarChain>>,
    pooled: Option<CovWelford>,
    n: u64,
}

impl OnlineDiag {
    pub fn push(&mut self, chain: usize, theta: &[f32]) {
        if self.pooled.is_none() {
            self.track = theta.len().min(MAX_TRACK);
            self.pooled = Some(CovWelford::new(self.track));
        }
        if theta.len() < self.track {
            // A sample narrower than the run's established dimension can
            // only come from a corrupt/hand-edited stream (`replay
            // --diag`); skip it rather than panic or poison the stats.
            return;
        }
        let track = self.track;
        let scalars =
            self.chains.entry(chain).or_insert_with(|| vec![ScalarChain::default(); track]);
        let mut buf = [0.0f64; MAX_TRACK];
        for j in 0..track {
            buf[j] = theta[j] as f64;
            scalars[j].push(buf[j]);
        }
        self.pooled.as_mut().expect("pooled initialized above").push(&buf[..track]);
        self.n += 1;
    }

    /// Per-coordinate `(split-R̂, chain-summed ESS)` over the tracked
    /// coordinates — the table `ecsgmcmc report` renders. [`Self::summary`]
    /// folds exactly these values, so the report's numbers and `replay
    /// --diag`'s always agree bit-for-bit.
    pub fn per_coordinate(&self) -> Vec<(f64, f64)> {
        (0..self.track)
            .map(|j| {
                let per_chain: Vec<Vec<f64>> =
                    self.chains.values().map(|c| c[j].values.clone()).collect();
                // Split-R̂ over completed batch means (exact draws while
                // the batch size is 1). Degenerate coordinates (zero
                // within-chain variance — e.g. untouched padding) return
                // NaN, skipped by the summary fold.
                let r = rhat::rhat(&per_chain);
                // ESS: Geyer per chain over batch means, rescaled by the
                // batch size (exact while it is 1), summed over chains.
                let mut ess_sum = 0.0;
                for scalars in self.chains.values() {
                    let c = &scalars[j];
                    let b = c.batch.max(1);
                    ess_sum += (ess::ess(&c.values) * b as f64).min(c.n as f64);
                }
                (r, ess_sum)
            })
            .collect()
    }

    /// `(chain id, samples folded)` per chain — fleet membership as the
    /// diagnostics saw it (`/status`, `ecsgmcmc report`).
    pub fn chain_counts(&self) -> Vec<(usize, u64)> {
        self.chains.iter().map(|(&id, s)| (id, s.first().map_or(0, |c| c.n))).collect()
    }

    /// Snapshot of the diagnostics; callable mid-run or at the end.
    pub fn summary(&self) -> OnlineDiagSummary {
        let mut max_rhat = f64::NAN;
        let mut min_ess = f64::NAN;
        for (r, ess_sum) in self.per_coordinate() {
            if r.is_finite() {
                max_rhat = if max_rhat.is_nan() { r } else { max_rhat.max(r) };
            }
            min_ess = if min_ess.is_nan() { ess_sum } else { min_ess.min(ess_sum) };
        }
        let batch = self
            .chains
            .values()
            .flat_map(|scalars| scalars.iter())
            .map(|c| c.batch.max(1))
            .max()
            .unwrap_or(0);
        let (mean, cov) = match &self.pooled {
            Some(p) => (p.mean().to_vec(), p.cov()),
            None => (Vec::new(), Vec::new()),
        };
        OnlineDiagSummary {
            n: self.n,
            chains: self.chains.len(),
            tracked: self.track,
            batch: batch.max(1),
            mean,
            cov,
            max_rhat,
            min_ess,
        }
    }
}

/// End-of-run (or mid-run) diagnostics snapshot, attached to
/// `RunResult::online_diag`.
#[derive(Debug, Clone)]
pub struct OnlineDiagSummary {
    /// Pooled samples folded in.
    pub n: u64,
    pub chains: usize,
    /// Leading θ coordinates the scalar diagnostics cover.
    pub tracked: usize,
    /// Largest batch size any scalar chain collapsed to; 1 means every
    /// estimate equals its exact whole-trace counterpart.
    pub batch: usize,
    /// Pooled mean over the tracked coordinates.
    pub mean: Vec<f64>,
    /// Row-major tracked×tracked pooled sample covariance.
    pub cov: Vec<f64>,
    /// Split-R̂ maximized over tracked coordinates (NaN if undefined).
    pub max_rhat: f64,
    /// Min over tracked coordinates of the per-chain-summed ESS.
    pub min_ess: f64,
}

/// The per-frame sink handle: forwards chain samples into the shared
/// accumulator; the center trajectory is not a sampling chain and is
/// ignored.
pub struct OnlineDiagSink {
    shared: Arc<Mutex<OnlineDiag>>,
    frame: Frame,
}

impl OnlineDiagSink {
    pub fn new(shared: Arc<Mutex<OnlineDiag>>, frame: Frame) -> OnlineDiagSink {
        OnlineDiagSink { shared, frame }
    }
}

impl SampleSink for OnlineDiagSink {
    fn record(&mut self, _t: f64, theta: &[f32]) {
        if let Frame::Chain(w) = self.frame {
            self.shared.lock().unwrap().push(w, theta);
        }
    }

    /// θ is folded into the accumulator and discarded by design — this
    /// sink never counts as retention for fan-out loss accounting.
    fn retains_samples(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diagnostics::{moments, to_f64_samples};
    use crate::math::rng::Pcg64;

    fn synth_chains(k: usize, n: usize, shift: f64, seed: u64) -> Vec<Vec<Vec<f32>>> {
        let mut rng = Pcg64::seeded(seed);
        (0..k)
            .map(|c| {
                (0..n)
                    .map(|_| {
                        vec![
                            (rng.next_normal() + shift * c as f64) as f32,
                            rng.next_normal() as f32,
                        ]
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn matches_posthoc_diagnostics_below_batch_cap() {
        let chains = synth_chains(4, 1500, 0.0, 5);
        let mut diag = OnlineDiag::default();
        for (c, chain) in chains.iter().enumerate() {
            for theta in chain {
                diag.push(c, theta);
            }
        }
        let s = diag.summary();
        assert_eq!(s.batch, 1);
        assert_eq!(s.chains, 4);
        assert_eq!(s.tracked, 2);
        assert_eq!(s.n, 4 * 1500);

        let per_chain_f64: Vec<Vec<Vec<f64>>> =
            chains.iter().map(|c| to_f64_samples(c, 2)).collect();
        let posthoc_rhat = rhat::max_rhat(&per_chain_f64);
        assert!((s.max_rhat - posthoc_rhat).abs() < 1e-12, "{} vs {posthoc_rhat}", s.max_rhat);

        let posthoc_min_ess = (0..2)
            .map(|j| {
                per_chain_f64
                    .iter()
                    .map(|c| ess::ess(&c.iter().map(|x| x[j]).collect::<Vec<_>>()))
                    .sum::<f64>()
            })
            .fold(f64::INFINITY, f64::min);
        assert!(
            (s.min_ess - posthoc_min_ess).abs() < 1e-9,
            "{} vs {posthoc_min_ess}",
            s.min_ess
        );

        let pooled: Vec<Vec<f64>> = per_chain_f64.iter().flatten().cloned().collect();
        let m = moments(&pooled);
        for j in 0..2 {
            assert!((s.mean[j] - m.mean[j]).abs() < 1e-9);
        }
        for i in 0..4 {
            assert!((s.cov[i] - m.cov[i]).abs() < 1e-9, "cov[{i}]");
        }
    }

    #[test]
    fn detects_shifted_chains() {
        let chains = synth_chains(4, 1000, 3.0, 6);
        let mut diag = OnlineDiag::default();
        for (c, chain) in chains.iter().enumerate() {
            for theta in chain {
                diag.push(c, theta);
            }
        }
        assert!(diag.summary().max_rhat > 1.5);
    }

    #[test]
    fn batch_collapse_bounds_memory_for_long_chains() {
        let mut chain = ScalarChain::default();
        let mut rng = Pcg64::seeded(7);
        let n = 3 * BATCH_CAP;
        let mut running_sum = 0.0;
        for _ in 0..n {
            let x = rng.next_normal();
            running_sum += x;
            chain.push(x);
        }
        assert!(chain.values.len() < BATCH_CAP, "not collapsed: {}", chain.values.len());
        assert!(chain.batch >= 2);
        assert_eq!(chain.n, n as u64);
        // Batch means preserve the overall mean exactly (complete batches).
        let complete = chain.values.len() * chain.batch;
        let stored_mean: f64 = chain.values.iter().sum::<f64>() / chain.values.len() as f64;
        let true_mean = (running_sum - chain.acc) / complete as f64;
        assert!((stored_mean - true_mean).abs() < 1e-9);
    }

    #[test]
    fn center_frame_is_ignored() {
        let shared = Arc::new(Mutex::new(OnlineDiag::default()));
        let mut center = OnlineDiagSink::new(shared.clone(), Frame::Center);
        center.record(0.0, &[1.0, 2.0]);
        let mut chain = OnlineDiagSink::new(shared.clone(), Frame::Chain(0));
        chain.record(0.0, &[1.0, 2.0]);
        assert_eq!(shared.lock().unwrap().n, 1);
    }

    #[test]
    fn short_theta_is_skipped_not_panicking() {
        let mut diag = OnlineDiag::default();
        diag.push(0, &[1.0, 2.0]);
        diag.push(0, &[3.0]); // corrupt stream line: narrower than track
        diag.push(0, &[5.0, 6.0]);
        assert_eq!(diag.summary().n, 2);
    }

    #[test]
    fn per_coordinate_and_chain_counts_agree_with_summary() {
        let chains = synth_chains(3, 800, 0.5, 9);
        let mut diag = OnlineDiag::default();
        for (c, chain) in chains.iter().enumerate() {
            for theta in chain {
                diag.push(c, theta);
            }
        }
        let s = diag.summary();
        let per = diag.per_coordinate();
        assert_eq!(per.len(), s.tracked);
        let max_rhat =
            per.iter().map(|p| p.0).filter(|r| r.is_finite()).fold(f64::NAN, f64::max);
        let min_ess = per.iter().map(|p| p.1).fold(f64::NAN, f64::min);
        assert_eq!(max_rhat.to_bits(), s.max_rhat.to_bits());
        assert_eq!(min_ess.to_bits(), s.min_ess.to_bits());
        let counts = diag.chain_counts();
        assert_eq!(counts, vec![(0, 800), (1, 800), (2, 800)]);
    }

    #[test]
    fn empty_summary_is_well_defined() {
        let s = OnlineDiag::default().summary();
        assert_eq!(s.n, 0);
        assert_eq!(s.chains, 0);
        assert!(s.max_rhat.is_nan());
        assert!(s.min_ess.is_nan());
        assert!(s.mean.is_empty());
    }
}
