//! Streaming JSONL sink: one self-describing event per line.
//!
//! Event schema (stream version 1; see DESIGN.md §7 for the full table):
//!
//! ```text
//! {"ev":"meta","version":1,"scheme":"ec","workers":4,"seed":42}
//! {"ev":"sample","chain":0,"t":0.0123,"theta":[0.5,-1.25]}
//! {"ev":"u","chain":0,"step":100,"t":0.0119,"u":1.875}
//! {"ev":"center","t":0.0125,"theta":[0.1,-0.9]}
//! {"ev":"metrics","total_steps":4000,...,"elapsed":0.42}
//! ```
//!
//! Framing: every event line carries its own frame tag (`chain` id, or
//! the `center` event kind), and [`JsonlWriter`] locks per *line* — so K
//! worker threads plus the center server stream concurrently with no
//! interleaving corruption and no cross-thread ordering requirement; the
//! reader re-groups by frame. Numbers go through the shared shortest
//! round-trip formatting in `util/json`, so replayed θ is bit-identical.

use super::{Frame, SampleSink};
use crate::coordinator::Metrics;
use crate::util::json::Emitter;
use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// Stream format version, bumped on schema changes.
pub const STREAM_VERSION: u64 = 1;

/// Line-atomic writer shared by every frame's [`JsonlSink`].
///
/// I/O failure policy: the first write error logs once and latches the
/// writer off — samplers must never die because a disk filled mid-run.
pub struct JsonlWriter {
    out: Mutex<BufWriter<File>>,
    failed: AtomicBool,
}

impl JsonlWriter {
    pub fn create(path: &Path) -> io::Result<JsonlWriter> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        Ok(JsonlWriter {
            out: Mutex::new(BufWriter::new(File::create(path)?)),
            failed: AtomicBool::new(false),
        })
    }

    /// Append one complete event line (the emitter escapes embedded
    /// newlines, so `text` never spans lines). Returns `false` when the
    /// event was discarded because the writer latched off on an earlier
    /// I/O error — callers count those toward their `dropped` totals so
    /// a mid-run disk failure is never silent.
    pub fn line(&self, text: &str) -> bool {
        if self.failed.load(Ordering::Relaxed) {
            return false;
        }
        let mut out = self.out.lock().unwrap();
        let wrote = out.write_all(text.as_bytes()).and_then(|_| out.write_all(b"\n"));
        if wrote.is_err() {
            if !self.failed.swap(true, Ordering::Relaxed) {
                crate::log_warn!("jsonl sink: write failed; dropping further stream events");
            }
            return false;
        }
        true
    }

    /// Run-header event. The seed travels as a string: our JSON numbers
    /// are f64, which would silently corrupt u64 seeds ≥ 2^53.
    pub fn meta(&self, scheme: &str, workers: usize, seed: u64) {
        let mut e = Emitter::new();
        e.begin_obj();
        e.key("ev").str_val("meta");
        e.key("version").num(STREAM_VERSION as f64);
        e.key("scheme").str_val(scheme);
        e.key("workers").num(workers as f64);
        e.key("seed").str_val(&seed.to_string());
        e.end_obj();
        self.line(e.as_str());
    }

    /// End-of-run metrics event.
    pub fn metrics(&self, m: &Metrics, elapsed: f64) {
        let mut e = Emitter::new();
        e.begin_obj();
        e.key("ev").str_val("metrics");
        e.key("total_steps").num(m.total_steps as f64);
        e.key("center_steps").num(m.center_steps as f64);
        e.key("exchanges").num(m.exchanges as f64);
        e.key("grads_computed").num(m.grads_computed as f64);
        e.key("steps_per_sec").num(m.steps_per_sec);
        e.key("samples_dropped").num(m.samples_dropped as f64);
        e.key("mean_staleness").num(m.mean_staleness());
        e.key("elapsed").num(elapsed);
        e.end_obj();
        self.line(e.as_str());
    }

    pub fn flush(&self) {
        if let Ok(mut out) = self.out.lock() {
            let _ = out.flush();
        }
    }

    #[cfg(test)]
    pub(crate) fn latch_failed_for_tests(&self) {
        self.failed.store(true, Ordering::Relaxed);
    }
}

/// Per-frame streaming sink. Peak resident sample memory is one event
/// line (the reused emitter buffer) — O(1) in run length, which is the
/// whole point: runs larger than RAM stream to disk without truncation.
pub struct JsonlSink {
    writer: Arc<JsonlWriter>,
    frame: Frame,
    emit: Emitter,
    /// Samples this frame offered after the writer latched off.
    dropped: u64,
}

impl JsonlSink {
    pub fn new(writer: Arc<JsonlWriter>, frame: Frame) -> JsonlSink {
        JsonlSink { writer, frame, emit: Emitter::new(), dropped: 0 }
    }
}

impl SampleSink for JsonlSink {
    fn record(&mut self, t: f64, theta: &[f32]) {
        self.emit.clear();
        self.emit.begin_obj();
        match self.frame {
            Frame::Chain(w) => {
                self.emit.key("ev").str_val("sample");
                self.emit.key("chain").num(w as f64);
            }
            Frame::Center => {
                self.emit.key("ev").str_val("center");
            }
        }
        self.emit.key("t").num(t);
        self.emit.key("theta").f32_arr(theta);
        self.emit.end_obj();
        if !self.writer.line(self.emit.as_str()) {
            self.dropped += 1;
        }
    }

    fn dropped(&self) -> u64 {
        self.dropped
    }

    fn record_u(&mut self, step: usize, t: f64, u: f64) {
        let Frame::Chain(w) = self.frame else {
            return; // the center trajectory has no Ũ trace
        };
        self.emit.clear();
        self.emit.begin_obj();
        self.emit.key("ev").str_val("u");
        self.emit.key("chain").num(w as f64);
        self.emit.key("step").num(step as f64);
        self.emit.key("t").num(t);
        self.emit.key("u").num(u);
        self.emit.end_obj();
        self.writer.line(self.emit.as_str());
    }

    fn flush(&mut self) {
        self.writer.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("ecsgmcmc-jsonl-{name}-{}", std::process::id()))
    }

    #[test]
    fn events_parse_back_line_by_line() {
        let path = tmp("events");
        let writer = Arc::new(JsonlWriter::create(&path).unwrap());
        writer.meta("ec", 4, 42);
        let mut sink = JsonlSink::new(writer.clone(), Frame::Chain(2));
        sink.record(0.5, &[1.5, -2.25]);
        sink.record_u(10, 0.4, 3.0);
        let mut center = JsonlSink::new(writer.clone(), Frame::Center);
        center.record(0.6, &[0.25]);
        center.record_u(5, 0.6, 1.0); // muted for the center frame
        writer.metrics(&Metrics::default(), 1.25);
        writer.flush();

        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 5);
        let v0 = Json::parse(lines[0]).unwrap();
        assert_eq!(v0.get("ev").unwrap().as_str(), Some("meta"));
        assert_eq!(v0.get("workers").unwrap().as_usize(), Some(4));
        let v1 = Json::parse(lines[1]).unwrap();
        assert_eq!(v1.get("ev").unwrap().as_str(), Some("sample"));
        assert_eq!(v1.get("chain").unwrap().as_usize(), Some(2));
        assert_eq!(v1.get("theta").unwrap().as_arr().unwrap().len(), 2);
        let v2 = Json::parse(lines[2]).unwrap();
        assert_eq!(v2.get("ev").unwrap().as_str(), Some("u"));
        assert_eq!(v2.get("step").unwrap().as_usize(), Some(10));
        let v3 = Json::parse(lines[3]).unwrap();
        assert_eq!(v3.get("ev").unwrap().as_str(), Some("center"));
        let v4 = Json::parse(lines[4]).unwrap();
        assert_eq!(v4.get("ev").unwrap().as_str(), Some("metrics"));
        assert_eq!(v4.get("elapsed").unwrap().as_f64(), Some(1.25));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn latched_writer_counts_discarded_samples_as_dropped() {
        let path = tmp("latched");
        let writer = Arc::new(JsonlWriter::create(&path).unwrap());
        let mut sink = JsonlSink::new(writer.clone(), Frame::Chain(0));
        sink.record(0.0, &[1.0]);
        assert_eq!(sink.dropped(), 0);
        // Simulate a mid-run I/O failure: everything after the latch is
        // discarded and must be accounted, not silently lost.
        writer.latch_failed_for_tests();
        sink.record(1.0, &[2.0]);
        sink.record(2.0, &[3.0]);
        assert_eq!(sink.dropped(), 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn concurrent_writers_never_interleave_within_a_line() {
        let path = tmp("concurrent");
        let writer = Arc::new(JsonlWriter::create(&path).unwrap());
        let threads: Vec<_> = (0..4)
            .map(|w| {
                let writer = writer.clone();
                std::thread::spawn(move || {
                    let mut sink = JsonlSink::new(writer, Frame::Chain(w));
                    for i in 0..200 {
                        sink.record(i as f64, &[w as f32, i as f32]);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        writer.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut counts = [0usize; 4];
        for line in text.lines() {
            let v = Json::parse(line).unwrap_or_else(|e| panic!("corrupt line: {e}: {line}"));
            let chain = v.get("chain").unwrap().as_usize().unwrap();
            let theta = v.get("theta").unwrap().as_arr().unwrap();
            assert_eq!(theta[0].as_f64().unwrap() as usize, chain);
            counts[chain] += 1;
        }
        assert_eq!(counts, [200; 4]);
        std::fs::remove_file(&path).ok();
    }
}
