//! Streaming JSONL sink: one self-describing event per line.
//!
//! Event schema (stream version 4; see DESIGN.md §7 for the full table):
//!
//! ```text
//! {"ev":"meta","version":4,"scheme":"ec","workers":4,"seed":"42",
//!  "dispatch":"simd","cpu":"x86_64 avx2 fma"}
//! {"ev":"sample","chain":0,"t":0.0123,"theta":[0.5,-1.25]}
//! {"ev":"u","chain":0,"step":100,"t":0.0119,"u":1.875}
//! {"ev":"center","t":0.0125,"theta":[0.1,-0.9]}
//! {"ev":"member","worker":5,"kind":"join","t":0.2}
//! {"ev":"checkpoint","step":400,"file":"out/ckpt/ckpt-000000000400.jsonl"}
//! {"ev":"telemetry","t":0.3,"center_steps":400,"stages":{...},...}
//! {"ev":"health","t":0.35,"center_steps":420,"status":"ok",...}
//! {"ev":"metrics","total_steps":4000,...,"elapsed":0.42}
//! ```
//!
//! Version history: v2 added the `member`/`checkpoint` events and the
//! `stale_rejects`/`worker_joins`/`worker_leaves` metrics keys
//! (elastic membership + checkpoint runtime, DESIGN.md §8). The
//! `dispatch`/`cpu` meta keys are schema-additive within v2 (kernel
//! dispatch, DESIGN.md §10) — replay ignores unknown keys. v3 added the
//! periodic `telemetry` event (full schema in `telemetry/event.rs` /
//! DESIGN.md §11) and the schema-additive `stage_*_count`/`stage_*_ns`
//! metrics keys; v2 streams parse unchanged. v4 added the `health`
//! event (run-health verdicts from the observatory, `observe/health.rs`
//! / DESIGN.md §13); it is emitted only when `[observe]` is enabled, so
//! observe-off streams differ from v3 only in the version number.
//!
//! Framing: every event line carries its own frame tag (`chain` id, or
//! the `center` event kind), and [`JsonlWriter`] locks per *line* — so K
//! worker threads plus the center server stream concurrently with no
//! interleaving corruption and no cross-thread ordering requirement; the
//! reader re-groups by frame. Numbers go through the shared shortest
//! round-trip formatting in `util/json`, so replayed θ is bit-identical.

use super::{Frame, SampleSink};
use crate::coordinator::Metrics;
use crate::util::json::Emitter;
use std::collections::VecDeque;
use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Stream format version, bumped on schema changes.
pub const STREAM_VERSION: u64 = 4;

/// Cap on lines buffered in memory while the writer is degraded; beyond
/// this, new lines are dropped *and counted* — never silently.
const PENDING_CAP: usize = 1024;

/// The lock-protected write state: the file plus the degraded-mode
/// buffer. Keeping both under ONE mutex preserves line order between
/// writes that hit the file and writes that buffer.
struct Inner {
    out: BufWriter<File>,
    /// Lines held in memory while degraded, drained FIFO on recovery.
    pending: VecDeque<String>,
}

/// Line-atomic writer shared by every frame's [`JsonlSink`].
///
/// I/O failure policy (DESIGN.md §12): a write error *degrades* the
/// writer instead of killing the fleet — subsequent lines buffer in
/// memory (bounded; overflow is dropped and counted) and every
/// [`flush`](Self::flush) retries the drain, so a transient failure
/// loses nothing and a permanent one loses a bounded, accounted tail.
/// A panic elsewhere never cascades either: a poisoned lock is
/// recovered, not `unwrap()`ed.
pub struct JsonlWriter {
    out: Mutex<Inner>,
    /// Terminal off-switch (unrecoverable conditions / tests): all
    /// subsequent lines are discarded and counted by callers.
    failed: AtomicBool,
    /// In degraded mode: lines buffer until a recovery drain succeeds.
    degraded: AtomicBool,
    /// Times the writer *entered* degraded mode (→ `sink_degraded`).
    degraded_events: AtomicU64,
    /// Lines dropped because the degraded buffer overflowed.
    dropped_lines: AtomicU64,
    /// The stream file, kept for checkpoint offset bookkeeping.
    path: std::path::PathBuf,
    /// Logical bytes appended so far (checkpoints record this so resume
    /// can truncate post-cut events, DESIGN.md §8). Advances only when a
    /// line durably reaches the file — buffered lines don't count until
    /// the recovery drain lands them.
    written: AtomicU64,
}

impl JsonlWriter {
    pub fn create(path: &Path) -> io::Result<JsonlWriter> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        Ok(Self::from_file(File::create(path)?, path, 0))
    }

    fn from_file(f: File, path: &Path, offset: u64) -> JsonlWriter {
        JsonlWriter {
            out: Mutex::new(Inner { out: BufWriter::new(f), pending: VecDeque::new() }),
            failed: AtomicBool::new(false),
            degraded: AtomicBool::new(false),
            degraded_events: AtomicU64::new(0),
            dropped_lines: AtomicU64::new(0),
            path: path.to_path_buf(),
            written: AtomicU64::new(offset),
        }
    }

    /// Lock the write state, recovering from a poisoned lock: a worker
    /// that panicked mid-write corrupts at most its own line, and the
    /// surviving fleet must keep streaming (the poisoned-mutex cascade
    /// this used to cause took down every thread).
    fn lock(&self) -> MutexGuard<'_, Inner> {
        match self.out.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Reopen an existing stream for a resumed run: truncate to the
    /// checkpointed byte offset (discarding any post-cut events the
    /// killed process wrote, including partial lines), then append.
    pub fn resume(path: &Path, offset: u64) -> io::Result<JsonlWriter> {
        let f = std::fs::OpenOptions::new().write(true).open(path)?;
        let len = f.metadata()?.len();
        if len < offset {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "stream {path:?} is {len} bytes but the checkpoint \
                     recorded {offset} — wrong or rewritten stream file"
                ),
            ));
        }
        f.set_len(offset)?;
        drop(f);
        let f = std::fs::OpenOptions::new().append(true).open(path)?;
        Ok(Self::from_file(f, path, offset))
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Logical bytes appended so far (what a checkpoint records).
    pub fn position(&self) -> u64 {
        self.written.load(Ordering::Relaxed)
    }

    /// Append one complete event line (the emitter escapes embedded
    /// newlines, so `text` never spans lines). Returns `false` when the
    /// event was discarded — either because the writer latched off
    /// terminally, or because the degraded-mode buffer overflowed —
    /// callers count those toward their `dropped` totals so a mid-run
    /// disk failure is never silent.
    pub fn line(&self, text: &str) -> bool {
        if self.failed.load(Ordering::Relaxed) {
            return false;
        }
        let mut inner = self.lock();
        if self.degraded.load(Ordering::Relaxed) {
            // Everything after a write failure buffers until a recovery
            // drain succeeds — writing past buffered lines would reorder
            // the stream.
            return self.buffer_line(&mut inner, text);
        }
        let wrote = if crate::faults::enabled() && crate::faults::sink_write_fault() {
            Err(io::Error::other("injected fault: sink write"))
        } else {
            inner.out.write_all(text.as_bytes()).and_then(|_| inner.out.write_all(b"\n"))
        };
        match wrote {
            Ok(()) => {
                self.written.fetch_add(text.len() as u64 + 1, Ordering::Relaxed);
                true
            }
            Err(e) => {
                self.degraded.store(true, Ordering::Relaxed);
                self.degraded_events.fetch_add(1, Ordering::Relaxed);
                crate::log_warn!(
                    "jsonl sink: write failed ({e}); buffering events in memory until a \
                     flush succeeds"
                );
                self.buffer_line(&mut inner, text)
            }
        }
    }

    /// Hold `text` in the degraded buffer (bounded; overflow drops and
    /// counts). Returns whether the line was retained.
    fn buffer_line(&self, inner: &mut Inner, text: &str) -> bool {
        if inner.pending.len() >= PENDING_CAP {
            self.dropped_lines.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        inner.pending.push_back(text.to_string());
        true
    }

    /// Attempt to leave degraded mode: replay the buffered lines in
    /// order. Stops at the first failure (stays degraded); on success
    /// the writer resumes direct writes.
    fn try_recover(&self, inner: &mut Inner) {
        if !self.degraded.load(Ordering::Relaxed) {
            return;
        }
        while let Some(text) = inner.pending.front() {
            let wrote = if crate::faults::enabled() && crate::faults::sink_write_fault() {
                Err(io::Error::other("injected fault: sink write"))
            } else {
                inner.out.write_all(text.as_bytes()).and_then(|_| inner.out.write_all(b"\n"))
            };
            match wrote {
                Ok(()) => {
                    self.written.fetch_add(text.len() as u64 + 1, Ordering::Relaxed);
                    inner.pending.pop_front();
                }
                Err(_) => return,
            }
        }
        self.degraded.store(false, Ordering::Relaxed);
        crate::log_warn!("jsonl sink: recovered; buffered events drained to disk");
    }

    /// Times the writer entered degraded mode (folds into the
    /// `sink_degraded` metric).
    pub fn degraded_events(&self) -> u64 {
        self.degraded_events.load(Ordering::Relaxed)
    }

    /// Lines lost to degraded-buffer overflow.
    pub fn dropped_lines(&self) -> u64 {
        self.dropped_lines.load(Ordering::Relaxed)
    }

    /// Run-header event. The seed travels as a string: our JSON numbers
    /// are f64, which would silently corrupt u64 seeds ≥ 2^53.
    /// `dispatch`/`cpu` are schema-additive (replay tolerates their
    /// absence in old streams): they record the kernel dispatch the run
    /// resolved to, so a stream can be audited for bit-reproducibility
    /// (DESIGN.md §10).
    pub fn meta(&self, scheme: &str, workers: usize, seed: u64) {
        let mut e = Emitter::new();
        e.begin_obj();
        e.key("ev").str_val("meta");
        e.key("version").num(STREAM_VERSION as f64);
        e.key("scheme").str_val(scheme);
        e.key("workers").num(workers as f64);
        e.key("seed").str_val(&seed.to_string());
        e.key("dispatch").str_val(crate::math::simd::kernel_kind().name());
        e.key("cpu").str_val(&crate::math::simd::cpu_features());
        e.end_obj();
        self.line(e.as_str());
    }

    /// End-of-run metrics event.
    pub fn metrics(&self, m: &Metrics, elapsed: f64) {
        let mut e = Emitter::new();
        e.begin_obj();
        e.key("ev").str_val("metrics");
        e.key("total_steps").num(m.total_steps as f64);
        e.key("center_steps").num(m.center_steps as f64);
        e.key("exchanges").num(m.exchanges as f64);
        e.key("grads_computed").num(m.grads_computed as f64);
        e.key("steps_per_sec").num(m.steps_per_sec);
        e.key("samples_dropped").num(m.samples_dropped as f64);
        e.key("stale_rejects").num(m.stale_rejects as f64);
        e.key("worker_joins").num(m.worker_joins as f64);
        e.key("worker_leaves").num(m.worker_leaves as f64);
        e.key("mean_staleness").num(m.mean_staleness());
        // Schema-additive stage totals (stream v3): absent unless the run
        // had telemetry on, so v2-era replays see byte-identical events.
        for (stage, count, ns) in &m.stage_totals {
            e.key(&format!("stage_{stage}_count")).num(*count as f64);
            e.key(&format!("stage_{stage}_ns")).num(*ns as f64);
        }
        // Schema-additive robustness counters (DESIGN.md §12): absent
        // when zero, so fault-free streams stay byte-identical.
        if m.faults_injected > 0 {
            e.key("faults_injected").num(m.faults_injected as f64);
        }
        if m.ckpt_retries > 0 {
            e.key("ckpt_retries").num(m.ckpt_retries as f64);
        }
        if m.sink_degraded > 0 {
            e.key("sink_degraded").num(m.sink_degraded as f64);
        }
        if m.worker_panics > 0 {
            e.key("worker_panics").num(m.worker_panics as f64);
        }
        e.key("elapsed").num(elapsed);
        e.end_obj();
        self.line(e.as_str());
    }

    /// Membership transition event (elastic fleets, DESIGN.md §8).
    /// `kind` is `"join"`, `"leave"` or `"fail"`.
    pub fn member(&self, t: f64, worker: usize, kind: &str) {
        let mut e = Emitter::new();
        e.begin_obj();
        e.key("ev").str_val("member");
        e.key("worker").num(worker as f64);
        e.key("kind").str_val(kind);
        e.key("t").num(t);
        e.end_obj();
        self.line(e.as_str());
    }

    /// Checkpoint marker: records that a snapshot covering everything
    /// up to `step` was persisted at `file`. Written *after* the offset
    /// a resume would truncate to, so a resumed stream simply lacks the
    /// marker of the cut it resumed from.
    pub fn checkpoint(&self, step: usize, file: &str) {
        let mut e = Emitter::new();
        e.begin_obj();
        e.key("ev").str_val("checkpoint");
        e.key("step").num(step as f64);
        e.key("file").str_val(file);
        e.end_obj();
        self.line(e.as_str());
    }

    /// Periodic telemetry frame (DESIGN.md §11): cumulative stage
    /// histograms, staleness/queue-depth quantiles, and the recent span
    /// window. Schema-additive — replay annotates it without touching
    /// the sample path.
    pub fn telemetry(&self, frame: &crate::telemetry::event::TelemetryFrame) {
        let mut e = Emitter::new();
        frame.emit(&mut e);
        self.line(e.as_str());
    }

    /// Run-health verdict (stream v4, DESIGN.md §13): the observatory's
    /// periodic assessment — stalled chains, divergence, staleness-gate
    /// pressure, ESS/sec trend. Schema-additive like `telemetry`; only
    /// written when `[observe]` is enabled.
    pub fn health(&self, h: &crate::observe::HealthSnapshot) {
        let mut e = Emitter::new();
        e.begin_obj();
        e.key("ev").str_val("health");
        e.key("t").num(h.t);
        e.key("center_steps").num(h.center_steps as f64);
        e.key("status").str_val(h.status.name());
        e.key("workers_active").num(h.workers_active as f64);
        e.key("stalled_chains").begin_arr();
        for w in &h.stalled {
            e.num(*w as f64);
        }
        e.end_arr();
        e.key("divergent").bool_val(h.divergent);
        e.key("theta_norm").num(h.theta_norm);
        e.key("reject_rate").num(h.reject_rate);
        e.key("ess_per_sec").num(h.ess_per_sec);
        e.key("ess_trend").num(h.ess_trend);
        e.key("reasons").begin_arr();
        for r in &h.reasons {
            e.str_val(r);
        }
        e.end_arr();
        e.end_obj();
        self.line(e.as_str());
    }

    pub fn flush(&self) {
        let _span = crate::telemetry::span(crate::telemetry::Stage::SinkFlush);
        let mut inner = self.lock();
        self.try_recover(&mut inner);
        let _ = inner.out.flush();
    }

    #[cfg(test)]
    pub(crate) fn latch_failed_for_tests(&self) {
        self.failed.store(true, Ordering::Relaxed);
    }

    /// Test hook: panic while holding the writer lock, poisoning it the
    /// way a dying worker mid-write would.
    #[cfg(test)]
    pub(crate) fn panic_while_locked_for_tests(&self) {
        let _guard = self.out.lock().unwrap();
        panic!("induced panic while holding the writer lock");
    }

    /// Test hook: force degraded mode without an I/O error, to exercise
    /// the buffer/drain path deterministically.
    #[cfg(test)]
    pub(crate) fn enter_degraded_for_tests(&self) {
        if !self.degraded.swap(true, Ordering::Relaxed) {
            self.degraded_events.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Per-frame streaming sink. Peak resident sample memory is one event
/// line (the reused emitter buffer) — O(1) in run length, which is the
/// whole point: runs larger than RAM stream to disk without truncation.
pub struct JsonlSink {
    writer: Arc<JsonlWriter>,
    frame: Frame,
    emit: Emitter,
    /// Samples this frame offered after the writer latched off.
    dropped: u64,
}

impl JsonlSink {
    pub fn new(writer: Arc<JsonlWriter>, frame: Frame) -> JsonlSink {
        JsonlSink { writer, frame, emit: Emitter::new(), dropped: 0 }
    }
}

impl SampleSink for JsonlSink {
    fn record(&mut self, t: f64, theta: &[f32]) {
        self.emit.clear();
        self.emit.begin_obj();
        match self.frame {
            Frame::Chain(w) => {
                self.emit.key("ev").str_val("sample");
                self.emit.key("chain").num(w as f64);
            }
            Frame::Center => {
                self.emit.key("ev").str_val("center");
            }
        }
        self.emit.key("t").num(t);
        self.emit.key("theta").f32_arr(theta);
        self.emit.end_obj();
        if !self.writer.line(self.emit.as_str()) {
            self.dropped += 1;
        }
    }

    fn dropped(&self) -> u64 {
        self.dropped
    }

    fn record_u(&mut self, step: usize, t: f64, u: f64) {
        let Frame::Chain(w) = self.frame else {
            return; // the center trajectory has no Ũ trace
        };
        self.emit.clear();
        self.emit.begin_obj();
        self.emit.key("ev").str_val("u");
        self.emit.key("chain").num(w as f64);
        self.emit.key("step").num(step as f64);
        self.emit.key("t").num(t);
        self.emit.key("u").num(u);
        self.emit.end_obj();
        self.writer.line(self.emit.as_str());
    }

    fn record_member(&mut self, t: f64, worker: usize, kind: &str) {
        self.writer.member(t, worker, kind);
    }

    fn flush(&mut self) {
        self.writer.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("ecsgmcmc-jsonl-{name}-{}", std::process::id()))
    }

    #[test]
    fn events_parse_back_line_by_line() {
        let path = tmp("events");
        let writer = Arc::new(JsonlWriter::create(&path).unwrap());
        writer.meta("ec", 4, 42);
        let mut sink = JsonlSink::new(writer.clone(), Frame::Chain(2));
        sink.record(0.5, &[1.5, -2.25]);
        sink.record_u(10, 0.4, 3.0);
        let mut center = JsonlSink::new(writer.clone(), Frame::Center);
        center.record(0.6, &[0.25]);
        center.record_u(5, 0.6, 1.0); // muted for the center frame
        writer.metrics(&Metrics::default(), 1.25);
        writer.flush();

        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 5);
        let v0 = Json::parse(lines[0]).unwrap();
        assert_eq!(v0.get("ev").unwrap().as_str(), Some("meta"));
        assert_eq!(v0.get("workers").unwrap().as_usize(), Some(4));
        let dispatch = v0.get("dispatch").unwrap().as_str().unwrap();
        assert!(dispatch == "scalar" || dispatch == "simd", "{dispatch}");
        assert!(!v0.get("cpu").unwrap().as_str().unwrap().is_empty());
        let v1 = Json::parse(lines[1]).unwrap();
        assert_eq!(v1.get("ev").unwrap().as_str(), Some("sample"));
        assert_eq!(v1.get("chain").unwrap().as_usize(), Some(2));
        assert_eq!(v1.get("theta").unwrap().as_arr().unwrap().len(), 2);
        let v2 = Json::parse(lines[2]).unwrap();
        assert_eq!(v2.get("ev").unwrap().as_str(), Some("u"));
        assert_eq!(v2.get("step").unwrap().as_usize(), Some(10));
        let v3 = Json::parse(lines[3]).unwrap();
        assert_eq!(v3.get("ev").unwrap().as_str(), Some("center"));
        let v4 = Json::parse(lines[4]).unwrap();
        assert_eq!(v4.get("ev").unwrap().as_str(), Some("metrics"));
        assert_eq!(v4.get("elapsed").unwrap().as_f64(), Some(1.25));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn latched_writer_counts_discarded_samples_as_dropped() {
        let path = tmp("latched");
        let writer = Arc::new(JsonlWriter::create(&path).unwrap());
        let mut sink = JsonlSink::new(writer.clone(), Frame::Chain(0));
        sink.record(0.0, &[1.0]);
        assert_eq!(sink.dropped(), 0);
        // Simulate a mid-run I/O failure: everything after the latch is
        // discarded and must be accounted, not silently lost.
        writer.latch_failed_for_tests();
        sink.record(1.0, &[2.0]);
        sink.record(2.0, &[3.0]);
        assert_eq!(sink.dropped(), 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn position_tracks_bytes_and_resume_truncates_post_cut_events() {
        let path = tmp("resume");
        let writer = Arc::new(JsonlWriter::create(&path).unwrap());
        writer.meta("ec", 2, 42);
        let mut sink = JsonlSink::new(writer.clone(), Frame::Chain(0));
        sink.record(0.5, &[1.0, 2.0]);
        writer.flush();
        let cut = writer.position();
        assert_eq!(cut, std::fs::metadata(&path).unwrap().len(), "position = file bytes");
        // Post-cut writes: a marker, a sample, and a torn partial line
        // (what a SIGKILL mid-write leaves behind).
        writer.checkpoint(40, "out/ckpt/c.jsonl");
        sink.record(0.6, &[3.0, 4.0]);
        writer.flush();
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"{\"ev\":\"sample\",\"chain\":0,\"t\":0.7,\"the").unwrap();
        drop(f);
        drop(sink);
        drop(writer);
        // Resume at the cut: the tail (marker + sample + torn line) is gone.
        let resumed = Arc::new(JsonlWriter::resume(&path, cut).unwrap());
        assert_eq!(resumed.position(), cut);
        let mut sink = JsonlSink::new(resumed.clone(), Frame::Chain(0));
        sink.record(0.6, &[3.0, 4.0]);
        sink.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "meta + pre-cut sample + resumed sample:\n{text}");
        for line in &lines {
            Json::parse(line).unwrap();
        }
        // Resuming past EOF is the wrong-file error, not silent corruption.
        let err = JsonlWriter::resume(&path, 1 << 40).unwrap_err();
        assert!(err.to_string().contains("checkpoint recorded"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn member_and_checkpoint_events_are_well_formed() {
        let path = tmp("member");
        let writer = Arc::new(JsonlWriter::create(&path).unwrap());
        writer.member(0.25, 3, "join");
        writer.member(0.5, 1, "fail");
        writer.checkpoint(400, "out/ckpt/ckpt-000000000400.jsonl");
        let mut sink = JsonlSink::new(writer.clone(), Frame::Center);
        sink.record_member(0.75, 0, "leave");
        writer.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].get("ev").unwrap().as_str(), Some("member"));
        assert_eq!(lines[0].get("kind").unwrap().as_str(), Some("join"));
        assert_eq!(lines[0].get("worker").unwrap().as_usize(), Some(3));
        assert_eq!(lines[2].get("ev").unwrap().as_str(), Some("checkpoint"));
        assert_eq!(lines[2].get("step").unwrap().as_usize(), Some(400));
        assert_eq!(lines[3].get("kind").unwrap().as_str(), Some("leave"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn health_events_are_well_formed_and_replayable() {
        let path = tmp("health");
        let writer = Arc::new(JsonlWriter::create(&path).unwrap());
        let snap = crate::observe::HealthSnapshot {
            status: crate::observe::HealthStatus::Degraded,
            t: 0.35,
            center_steps: 420,
            workers_active: 3,
            stalled: vec![1, 2],
            divergent: false,
            theta_norm: 2.5,
            reject_rate: 0.125,
            ess_per_sec: f64::NAN,
            ess_trend: 0.0,
            reasons: vec!["chain 1 stalled".to_string()],
        };
        writer.health(&snap);
        writer.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(v.get("ev").unwrap().as_str(), Some("health"));
        assert_eq!(v.get("status").unwrap().as_str(), Some("degraded"));
        assert_eq!(v.get("workers_active").unwrap().as_usize(), Some(3));
        let stalled = v.get("stalled_chains").unwrap().as_arr().unwrap();
        assert_eq!(stalled.len(), 2);
        // Non-finite ESS rate serializes as null, replays as NaN.
        assert!(matches!(v.get("ess_per_sec"), Some(Json::Null)));
        match crate::sink::replay::RunEvent::from_json(&v).unwrap() {
            crate::sink::replay::RunEvent::Health { t, json } => {
                assert!((t - 0.35).abs() < 1e-12);
                assert_eq!(json.get("status").unwrap().as_str(), Some("degraded"));
            }
            other => panic!("{other:?}"),
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn u64_seed_round_trips_writer_to_replay_without_f64_corruption() {
        // The satellite fix for the hazard flagged here: seeds ≥ 2^53
        // must survive the meta event exactly, which is why they travel
        // as strings. This drives the real writer → real reader path.
        let path = tmp("bigseed");
        let seed = u64::MAX - 12345; // corrupts if it ever touches f64
        assert_ne!(seed, (seed as f64) as u64, "seed must be outside f64 range");
        let writer = Arc::new(JsonlWriter::create(&path).unwrap());
        writer.meta("ec", 4, seed);
        writer.flush();
        let file = std::fs::File::open(&path).unwrap();
        let mut got = None;
        crate::sink::replay::scan_stream(file, |ev| {
            if let crate::sink::replay::RunEvent::Meta { seed, .. } = ev {
                got = Some(seed);
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(got, Some(seed));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn poisoned_lock_recovers_instead_of_cascading() {
        // The PR-8 satellite fix: one worker dying mid-write used to
        // poison the shared mutex, and the `.unwrap()` in `line()` then
        // panicked every surviving thread. Now the guard is recovered
        // and the fleet keeps streaming.
        let path = tmp("poison");
        let writer = Arc::new(JsonlWriter::create(&path).unwrap());
        let poisoner = writer.clone();
        let died = std::thread::spawn(move || poisoner.panic_while_locked_for_tests()).join();
        assert!(died.is_err(), "the poisoning thread must have panicked");
        let mut sink = JsonlSink::new(writer.clone(), Frame::Chain(0));
        sink.record(0.5, &[1.0, 2.0]);
        writer.flush();
        assert_eq!(sink.dropped(), 0, "survivors must not drop events");
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 1, "{text}");
        let v = Json::parse(lines[0]).unwrap();
        assert_eq!(v.get("ev").unwrap().as_str(), Some("sample"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn degraded_writer_buffers_then_drains_in_order() {
        let path = tmp("degraded");
        let writer = Arc::new(JsonlWriter::create(&path).unwrap());
        let mut sink = JsonlSink::new(writer.clone(), Frame::Chain(0));
        sink.record(0.0, &[0.0]);
        writer.flush();
        let before = writer.position();
        // Degrade: subsequent events buffer in memory, and `position()`
        // (what a checkpoint would record) must NOT advance — those
        // bytes aren't durable yet.
        writer.enter_degraded_for_tests();
        sink.record(1.0, &[1.0]);
        sink.record(2.0, &[2.0]);
        assert_eq!(writer.position(), before, "buffered lines are not durable");
        assert_eq!(writer.degraded_events(), 1);
        assert_eq!(sink.dropped(), 0, "buffered ≠ dropped");
        // Recovery drain on flush: the buffered tail lands in order.
        writer.flush();
        assert!(writer.position() > before);
        let text = std::fs::read_to_string(&path).unwrap();
        let ts: Vec<f64> = text
            .lines()
            .map(|l| Json::parse(l).unwrap().get("t").unwrap().as_f64().unwrap())
            .collect();
        assert_eq!(ts, vec![0.0, 1.0, 2.0], "drain preserves event order:\n{text}");
        assert_eq!(writer.position(), std::fs::metadata(&path).unwrap().len());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn degraded_buffer_overflow_drops_and_counts() {
        let path = tmp("overflow");
        let writer = Arc::new(JsonlWriter::create(&path).unwrap());
        writer.enter_degraded_for_tests();
        let mut sink = JsonlSink::new(writer.clone(), Frame::Chain(0));
        for i in 0..(PENDING_CAP + 7) {
            sink.record(i as f64, &[0.0]);
        }
        assert_eq!(writer.dropped_lines(), 7);
        assert_eq!(sink.dropped(), 7, "overflow drops count toward the frame's total");
        writer.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), PENDING_CAP, "the capped buffer drained");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn metrics_event_emits_fault_keys_only_when_nonzero() {
        let path = tmp("faultkeys");
        let writer = Arc::new(JsonlWriter::create(&path).unwrap());
        writer.metrics(&Metrics::default(), 0.5);
        let m = Metrics {
            faults_injected: 3,
            ckpt_retries: 2,
            sink_degraded: 1,
            worker_panics: 1,
            ..Default::default()
        };
        writer.metrics(&m, 0.5);
        writer.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        for key in ["faults_injected", "ckpt_retries", "sink_degraded", "worker_panics"] {
            assert!(!lines[0].contains(key), "zero counters stay absent: {}", lines[0]);
            assert!(lines[1].contains(key), "nonzero counters appear: {}", lines[1]);
        }
        let v = Json::parse(lines[1]).unwrap();
        assert_eq!(v.get("faults_injected").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("worker_panics").unwrap().as_usize(), Some(1));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn concurrent_writers_never_interleave_within_a_line() {
        let path = tmp("concurrent");
        let writer = Arc::new(JsonlWriter::create(&path).unwrap());
        let threads: Vec<_> = (0..4)
            .map(|w| {
                let writer = writer.clone();
                std::thread::spawn(move || {
                    let mut sink = JsonlSink::new(writer, Frame::Chain(w));
                    for i in 0..200 {
                        sink.record(i as f64, &[w as f32, i as f32]);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        writer.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut counts = [0usize; 4];
        for line in text.lines() {
            let v = Json::parse(line).unwrap_or_else(|e| panic!("corrupt line: {e}: {line}"));
            let chain = v.get("chain").unwrap().as_usize().unwrap();
            let theta = v.get("theta").unwrap().as_arr().unwrap();
            assert_eq!(theta[0].as_f64().unwrap() as usize, chain);
            counts[chain] += 1;
        }
        assert_eq!(counts, [200; 4]);
        std::fs::remove_file(&path).ok();
    }
}
