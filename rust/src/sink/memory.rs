//! In-memory sink: the pre-streaming recorder behavior, bit-compatible.

use super::SampleSink;

/// Retains samples in memory up to `cap`, counting — instead of silently
/// swallowing — everything offered beyond it. With this sink installed
/// (the default), every scheme produces byte-identical samples to the
/// pre-sink recorder: same thinning, same burn-in (both applied upstream
/// by the `Recorder`), same cap.
#[derive(Debug)]
pub struct MemorySink {
    cap: usize,
    samples: Vec<(f64, Vec<f32>)>,
    dropped: u64,
}

impl MemorySink {
    pub fn new(cap: usize) -> MemorySink {
        MemorySink { cap, samples: Vec::new(), dropped: 0 }
    }
}

impl SampleSink for MemorySink {
    fn record(&mut self, t: f64, theta: &[f32]) {
        if self.samples.len() < self.cap {
            self.samples.push((t, theta.to_vec()));
        } else {
            self.dropped += 1;
        }
    }

    fn dropped(&self) -> u64 {
        self.dropped
    }

    fn take_samples(&mut self) -> Vec<(f64, Vec<f32>)> {
        std::mem::take(&mut self.samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn caps_and_counts_overflow() {
        let mut s = MemorySink::new(3);
        for i in 0..10 {
            s.record(i as f64, &[i as f32]);
        }
        assert_eq!(s.dropped(), 7);
        let kept = s.take_samples();
        assert_eq!(kept.len(), 3);
        assert_eq!(kept[2].1, vec![2.0]);
        // Drained; a second take is empty but dropped stays reported.
        assert!(s.take_samples().is_empty());
        assert_eq!(s.dropped(), 7);
    }

    #[test]
    fn zero_cap_drops_everything() {
        let mut s = MemorySink::new(0);
        s.record(0.0, &[1.0]);
        assert_eq!(s.dropped(), 1);
        assert!(s.take_samples().is_empty());
    }
}
