//! Streaming sample sinks (DESIGN.md §7): where a run's recorded output
//! goes, with memory bounded by policy instead of by run length.
//!
//! Before this subsystem every chain eagerly buffered `(wall-time, θ)`
//! pairs into `Vec`s silently capped at `max_samples`, and diagnostics
//! only ran post-hoc over the full in-memory trace. A [`SampleSink`] is
//! the push-side contract the shared worker loop
//! (`coordinator/topology.rs`) and the EC center server write into
//! instead; what happens to each sample is a run-configuration choice
//! ([`SinkSpec`] on `RunOptions`):
//!
//! * [`MemorySink`] — today's behavior, made honest: retain up to
//!   `max_samples`, *count* (instead of silently swallowing) overflow;
//! * [`JsonlSink`] — stream every event to a JSONL file through the
//!   incremental emitter; peak resident sample memory is one record;
//! * [`OnlineDiagSink`] — fold samples into running moments and
//!   convergence diagnostics (Welford mean/cov, split-R̂, ESS) without
//!   retaining θ;
//! * [`TeeSink`] — fan one frame's events out to several of the above.
//!
//! The pull side lives in [`replay`]: a bounded-memory scan over a
//! stream file that reconstructs a `RunResult` or re-computes
//! diagnostics, making every streamed run a replayable artifact.

pub mod diag;
pub mod jsonl;
pub mod memory;
pub mod replay;
pub mod tee;

pub use diag::{OnlineDiag, OnlineDiagSink, OnlineDiagSummary};
pub use jsonl::{JsonlSink, JsonlWriter};
pub use memory::MemorySink;
pub use tee::TeeSink;

use crate::coordinator::RunResult;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Which stream of a run an event belongs to: one of the K worker
/// chains, or the EC center trajectory. Every JSONL event line carries
/// its frame, so concurrent writers need no cross-thread ordering — the
/// reader re-groups by frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Frame {
    Chain(usize),
    Center,
}

/// Consumer of one frame's recorded output. Implementations are `Send`
/// (each lives on its frame's thread) and share cross-frame resources —
/// the JSONL writer, the diagnostics accumulator — internally.
pub trait SampleSink: Send {
    /// Offer one post-burn-in, post-thinning (wall-time, θ) sample.
    fn record(&mut self, t: f64, theta: &[f32]);

    /// Offer one Ũ trace point (every `log_every` steps).
    fn record_u(&mut self, step: usize, t: f64, u: f64) {
        let _ = (step, t, u);
    }

    /// Offer one membership transition (elastic fleets, DESIGN.md §8):
    /// `kind` is `"join"`, `"leave"` or `"fail"`. Only streaming sinks
    /// record these; the default discards them.
    fn record_member(&mut self, t: f64, worker: usize, kind: &str) {
        let _ = (t, worker, kind);
    }

    /// Samples offered to this sink that ended up retained *nowhere*
    /// (e.g. past the in-memory cap with no stream attached). Surfaced
    /// in `Metrics::samples_dropped` instead of silently vanishing.
    fn dropped(&self) -> u64 {
        0
    }

    /// Whether this sink retains offered θ at all (in memory or on a
    /// stream). Diagnostics-only and muted sinks return `false`; fan-out
    /// loss accounting ignores them, so "dropped" always means "a θ the
    /// run tried to record is gone", never "a sink that by design keeps
    /// no θ kept no θ".
    fn retains_samples(&self) -> bool {
        true
    }

    /// Drain whatever the sink retained in memory; streaming sinks
    /// return empty.
    fn take_samples(&mut self) -> Vec<(f64, Vec<f32>)> {
        Vec::new()
    }

    /// Flush buffered output at end of frame.
    fn flush(&mut self) {}
}

/// A sink that swallows everything — for frames whose recording is muted
/// (the naive scheme's gradient-oracle workers).
pub struct NullSink;

impl SampleSink for NullSink {
    fn record(&mut self, _t: f64, _theta: &[f32]) {}

    fn retains_samples(&self) -> bool {
        false
    }
}

/// Declarative sink selection, carried by `RunOptions` so every scheme
/// driver builds the same pipeline from config/CLI without new plumbing.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum SinkSpec {
    /// Retain samples in `ChainTrace::samples` (the pre-sink default).
    #[default]
    Memory,
    /// Stream events to a JSONL file.
    Jsonl { path: PathBuf },
    /// Online convergence diagnostics only; θ is never retained.
    OnlineDiag,
    /// Fan out to several sinks.
    Tee(Vec<SinkSpec>),
}

impl SinkSpec {
    /// First JSONL stream path in this spec tree, if any — what run
    /// summaries should point the user at.
    pub fn jsonl_path(&self) -> Option<&Path> {
        match self {
            SinkSpec::Jsonl { path } => Some(path),
            SinkSpec::Tee(parts) => parts.iter().find_map(|p| p.jsonl_path()),
            SinkSpec::Memory | SinkSpec::OnlineDiag => None,
        }
    }
}

/// A [`SinkSpec`] with its shared resources resolved: files opened once,
/// accumulators allocated once, `Arc`s handed to every frame sink.
enum Built {
    Memory,
    Jsonl(Arc<JsonlWriter>),
    OnlineDiag(Arc<Mutex<OnlineDiag>>),
    Tee(Vec<Built>),
}

/// Per-run sink factory: resolves the spec once, hands out per-frame
/// [`SampleSink`]s sharing those resources, and finalizes the run
/// (dropped-count aggregation, metrics event, diagnostics summary).
pub struct SinkHub {
    built: Built,
    writers: Vec<Arc<JsonlWriter>>,
    diags: Vec<Arc<Mutex<OnlineDiag>>>,
}

impl SinkHub {
    pub fn new(spec: &SinkSpec) -> io::Result<SinkHub> {
        let mut writers = Vec::new();
        let mut diags = Vec::new();
        let built = build(spec, &mut writers, &mut diags, None)?;
        Ok(SinkHub { built, writers, diags })
    }

    /// Rebuild the hub for a *resumed* run (DESIGN.md §8): every JSONL
    /// stream is truncated to the byte offset its checkpoint recorded
    /// (discarding post-cut events from the killed process) and reopened
    /// for append. `offsets` maps stream paths (as recorded by
    /// [`SinkHub::stream_positions`]) to byte offsets; a stream in the
    /// spec with no recorded offset is an error — resuming into the
    /// wrong sink configuration silently corrupting a run artifact is
    /// exactly what this subsystem exists to prevent.
    pub fn resume(spec: &SinkSpec, offsets: &[(String, u64)]) -> io::Result<SinkHub> {
        let mut writers = Vec::new();
        let mut diags = Vec::new();
        let built = build(spec, &mut writers, &mut diags, Some(offsets))?;
        Ok(SinkHub { built, writers, diags })
    }

    /// Current (path, logical byte offset) of every attached stream,
    /// flushed first so the offsets are durable on disk.
    pub fn stream_positions(&self) -> Vec<(String, u64)> {
        self.writers
            .iter()
            .map(|w| {
                w.flush();
                (w.path().display().to_string(), w.position())
            })
            .collect()
    }

    /// The first attached JSONL writer, if any — where periodic
    /// telemetry frames go (telemetry is run-global, not per-stream, so
    /// mirroring it to every tee'd stream would only duplicate bytes).
    pub fn primary_writer(&self) -> Option<Arc<JsonlWriter>> {
        self.writers.first().cloned()
    }

    /// The online-diagnostics accumulator a finished run reports from
    /// (`finish()` summarizes `diags.last()`), if any — the observatory
    /// reads live split-R̂/ESS from the same accumulator so `/status`
    /// and the end-of-run summary can never disagree.
    pub fn primary_diag(&self) -> Option<Arc<Mutex<OnlineDiag>>> {
        self.diags.last().cloned()
    }

    /// Append a checkpoint marker to every attached stream.
    pub fn write_checkpoint_marker(&self, step: usize, file: &str) {
        for w in &self.writers {
            w.checkpoint(step, file);
        }
    }

    /// Plain in-memory recording, for callers that bypass `RunOptions`.
    pub fn memory() -> SinkHub {
        SinkHub::new(&SinkSpec::Memory).expect("memory sink is infallible")
    }

    /// Build the sink for one frame. `max_samples` is the in-memory
    /// retention cap (streaming sinks ignore it).
    pub fn frame_sink(&self, frame: Frame, max_samples: usize) -> Box<dyn SampleSink> {
        make(&self.built, frame, max_samples)
    }

    /// Write the run-header event to any attached stream.
    pub fn write_meta(&self, scheme: &str, workers: usize, seed: u64) {
        for w in &self.writers {
            w.meta(scheme, workers, seed);
        }
    }

    /// Finalize: fold per-chain dropped counts into the metrics, attach
    /// the online-diagnostics summary, append the metrics event and
    /// flush any stream. Call once, after the driver filled `result`.
    pub fn finish(&self, result: &mut RunResult) {
        result.metrics.samples_dropped +=
            result.chains.iter().map(|c| c.dropped).sum::<u64>();
        if let Some(diag) = self.diags.last() {
            result.online_diag = Some(diag.lock().unwrap().summary());
        }
        // Give degraded writers a recovery chance *before* the metrics
        // event, so the degraded count folded below is final and the
        // metrics line itself lands on disk (or in the drain buffer)
        // last, as usual.
        for w in &self.writers {
            w.flush();
            result.metrics.sink_degraded += w.degraded_events();
        }
        for w in &self.writers {
            w.metrics(&result.metrics, result.elapsed);
            w.flush();
        }
    }
}

fn build(
    spec: &SinkSpec,
    writers: &mut Vec<Arc<JsonlWriter>>,
    diags: &mut Vec<Arc<Mutex<OnlineDiag>>>,
    resume_offsets: Option<&[(String, u64)]>,
) -> io::Result<Built> {
    Ok(match spec {
        SinkSpec::Memory => Built::Memory,
        SinkSpec::Jsonl { path } => {
            let writer = match resume_offsets {
                None => Arc::new(JsonlWriter::create(path)?),
                Some(offsets) => {
                    let key = path.display().to_string();
                    let offset = offsets
                        .iter()
                        .find(|(p, _)| *p == key)
                        .map(|(_, o)| *o)
                        .ok_or_else(|| {
                            io::Error::new(
                                io::ErrorKind::InvalidInput,
                                format!(
                                    "checkpoint recorded no byte offset for stream \
                                     {key:?} — resume with the sink configuration \
                                     the run was started with"
                                ),
                            )
                        })?;
                    Arc::new(JsonlWriter::resume(path, offset)?)
                }
            };
            writers.push(writer.clone());
            Built::Jsonl(writer)
        }
        SinkSpec::OnlineDiag => {
            let diag = Arc::new(Mutex::new(OnlineDiag::default()));
            diags.push(diag.clone());
            Built::OnlineDiag(diag)
        }
        SinkSpec::Tee(parts) => Built::Tee(
            parts
                .iter()
                .map(|p| build(p, writers, diags, resume_offsets))
                .collect::<io::Result<_>>()?,
        ),
    })
}

fn make(built: &Built, frame: Frame, max_samples: usize) -> Box<dyn SampleSink> {
    match built {
        Built::Memory => Box::new(MemorySink::new(max_samples)),
        Built::Jsonl(writer) => Box::new(JsonlSink::new(writer.clone(), frame)),
        Built::OnlineDiag(diag) => Box::new(OnlineDiagSink::new(diag.clone(), frame)),
        Built::Tee(parts) => {
            Box::new(TeeSink::new(parts.iter().map(|p| make(p, frame, max_samples)).collect()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsonl_path_finds_the_stream_file() {
        let p = PathBuf::from("x.jsonl");
        assert_eq!(SinkSpec::Memory.jsonl_path(), None);
        assert_eq!(SinkSpec::OnlineDiag.jsonl_path(), None);
        assert_eq!(SinkSpec::Jsonl { path: p.clone() }.jsonl_path(), Some(p.as_path()));
        let tee = SinkSpec::Tee(vec![
            SinkSpec::Memory,
            SinkSpec::Jsonl { path: p.clone() },
            SinkSpec::OnlineDiag,
        ]);
        assert_eq!(tee.jsonl_path(), Some(p.as_path()));
    }

    #[test]
    fn null_sink_retains_nothing() {
        let mut s = NullSink;
        s.record(0.1, &[1.0]);
        s.record_u(0, 0.1, 2.0);
        assert_eq!(s.dropped(), 0);
        assert!(s.take_samples().is_empty());
    }

    #[test]
    fn memory_hub_round_trip() {
        let hub = SinkHub::memory();
        let mut sink = hub.frame_sink(Frame::Chain(0), 2);
        sink.record(0.0, &[1.0]);
        sink.record(1.0, &[2.0]);
        sink.record(2.0, &[3.0]);
        assert_eq!(sink.dropped(), 1);
        let kept = sink.take_samples();
        assert_eq!(kept.len(), 2);
        assert_eq!(kept[1].1, vec![2.0]);
    }
}
