//! The read side of the stream pipeline: scan a JSONL run stream with
//! bounded memory and either reconstruct a `RunResult` (runs become
//! replayable artifacts) or re-compute diagnostics without ever holding
//! the full sample set.

use super::diag::{OnlineDiag, OnlineDiagSummary};
use super::jsonl::STREAM_VERSION;
use crate::coordinator::{ChainTrace, Metrics, RunResult, TracePoint};
use crate::util::json::{Json, StreamReader};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fs::File;
use std::io::Read;
use std::path::Path;

/// One parsed stream event (schema in `sink/jsonl.rs` / DESIGN.md §7).
#[derive(Debug, Clone)]
pub enum RunEvent {
    Meta { version: u64, scheme: String, workers: usize, seed: u64 },
    Sample { chain: usize, t: f64, theta: Vec<f32> },
    U { chain: usize, step: usize, t: f64, u: f64 },
    Center { t: f64, theta: Vec<f32> },
    /// Membership transition (stream v2): `kind` ∈ join|leave|fail.
    Member { worker: usize, kind: String, t: f64 },
    /// Checkpoint marker (stream v2): a snapshot covering everything up
    /// to `step` was persisted at `file`.
    Checkpoint { step: usize, file: String },
    /// Periodic telemetry frame (stream v3, DESIGN.md §11): per-stage
    /// latency histograms, staleness/queue-depth quantiles and a compact
    /// span window. The full parsed object rides along so consumers
    /// (`ecsgmcmc trace`/`top`) read the schema-additive payload without
    /// this enum chasing every key.
    Telemetry { t: f64, json: Json },
    /// Run-health verdict (stream v4, DESIGN.md §13): the observatory's
    /// periodic status/stall/divergence/pressure assessment. Carried as
    /// the full parsed object, like `Telemetry`, so `top`/`report` read
    /// the schema-additive payload without this enum chasing keys.
    Health { t: f64, json: Json },
    Metrics { metrics: Metrics, elapsed: f64 },
}

impl RunEvent {
    pub fn from_json(v: &Json) -> Result<RunEvent> {
        let ev = v.get("ev").and_then(Json::as_str).context("event missing 'ev'")?;
        Ok(match ev {
            "meta" => {
                let version = v.get("version").and_then(Json::as_f64).unwrap_or(1.0) as u64;
                if version > STREAM_VERSION {
                    bail!(
                        "unsupported stream version {version} \
                         (this reader supports <= {STREAM_VERSION})"
                    );
                }
                RunEvent::Meta {
                    version,
                    scheme: v.get("scheme").and_then(Json::as_str).unwrap_or("?").to_string(),
                    workers: v.get("workers").and_then(Json::as_usize).unwrap_or(0),
                    // Emitted as a string (u64 seeds don't fit f64);
                    // tolerate numeric seeds from hand-written streams.
                    seed: match v.get("seed") {
                        Some(Json::Str(s)) => s.parse().unwrap_or(0),
                        Some(j) => j.as_f64().unwrap_or(0.0) as u64,
                        None => 0,
                    },
                }
            }
            "sample" => RunEvent::Sample {
                chain: v.get("chain").and_then(Json::as_usize).context("sample: chain")?,
                t: num_or_nan(v, "t").context("sample: t")?,
                theta: theta_arr(v.get("theta").context("sample: theta")?)?,
            },
            "u" => RunEvent::U {
                chain: v.get("chain").and_then(Json::as_usize).context("u: chain")?,
                step: v.get("step").and_then(Json::as_usize).context("u: step")?,
                t: num_or_nan(v, "t").context("u: t")?,
                u: num_or_nan(v, "u").context("u: u")?,
            },
            "center" => RunEvent::Center {
                t: num_or_nan(v, "t").context("center: t")?,
                theta: theta_arr(v.get("theta").context("center: theta")?)?,
            },
            "member" => RunEvent::Member {
                worker: v.get("worker").and_then(Json::as_usize).context("member: worker")?,
                kind: v.get("kind").and_then(Json::as_str).unwrap_or("?").to_string(),
                t: num_or_nan(v, "t").unwrap_or(f64::NAN),
            },
            "checkpoint" => RunEvent::Checkpoint {
                step: v.get("step").and_then(Json::as_usize).context("checkpoint: step")?,
                file: v.get("file").and_then(Json::as_str).unwrap_or("").to_string(),
            },
            "telemetry" => RunEvent::Telemetry {
                t: num_or_nan(v, "t").unwrap_or(f64::NAN),
                json: v.clone(),
            },
            "health" => RunEvent::Health {
                t: num_or_nan(v, "t").unwrap_or(f64::NAN),
                json: v.clone(),
            },
            "metrics" => RunEvent::Metrics {
                metrics: Metrics::from_json(v),
                elapsed: num_or_nan(v, "elapsed").unwrap_or(0.0),
            },
            other => bail!("unknown event kind '{other}'"),
        })
    }
}

/// Numeric field that may legitimately be null (the emitter writes
/// non-finite values as null); absent keys are an error.
fn num_or_nan(v: &Json, key: &str) -> Option<f64> {
    let field = v.get(key)?;
    Some(field.as_f64().unwrap_or(f64::NAN))
}

/// θ must be an array; `null` elements (non-finite at emit time) become
/// NaN, but a non-array θ is a malformed stream, not an empty sample.
fn theta_arr(v: &Json) -> Result<Vec<f32>> {
    match v.as_arr() {
        Some(arr) => {
            Ok(arr.iter().map(|x| x.as_f64().map(|f| f as f32).unwrap_or(f32::NAN)).collect())
        }
        None => bail!("theta must be an array"),
    }
}

/// Incrementally parse a JSONL run stream, invoking `on_event` per
/// event. Memory is bounded by one line regardless of stream length.
/// Every rejection — malformed JSON *or* well-formed JSON that is not a
/// valid event — names the 1-based line it came from.
pub fn scan_stream<R: Read>(
    mut src: R,
    mut on_event: impl FnMut(RunEvent) -> Result<()>,
) -> Result<()> {
    let mut reader = StreamReader::new();
    let mut chunk = [0u8; 64 * 1024];
    loop {
        let n = src.read(&mut chunk).context("reading stream")?;
        if n == 0 {
            break;
        }
        reader.feed(&chunk[..n]);
        while let Some(value) = reader.next_value() {
            let ev = RunEvent::from_json(&value?)
                .with_context(|| format!("line {}", reader.line()))?;
            on_event(ev)?;
        }
    }
    if let Some(value) = reader.finish() {
        let ev = RunEvent::from_json(&value?)
            .with_context(|| format!("line {}", reader.line()))?;
        on_event(ev)?;
    }
    Ok(())
}

/// What `ecsgmcmc fsck` reports for a run stream: how much of the file
/// is an intact event prefix, and where the salvage point is. A damaged
/// stream can be recovered by truncating it to `bytes_salvaged` bytes
/// (`head -c`), after which it replays cleanly.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SalvageReport {
    /// Events decoded from the intact prefix.
    pub events: u64,
    /// Distinct chains with at least one recovered sample.
    pub chains: usize,
    /// Sample events recovered.
    pub samples: u64,
    /// Total file size (bytes).
    pub bytes_total: u64,
    /// Length of the last intact prefix: every byte before this decodes,
    /// every byte after is damage (or a clean file's own length).
    pub bytes_salvaged: u64,
    /// Whether any bytes had to be discarded.
    pub truncated: bool,
    /// First rejection, naming its line; `None` for an intact stream.
    pub error: Option<String>,
}

/// Scan a stream file leniently: decode events until the first damaged
/// line, then report the intact prefix instead of failing. The strict
/// readers ([`replay_file`], [`stream_diag`]) stay strict; this is the
/// recovery path (`ecsgmcmc fsck`, and `replay` on truncated streams).
pub fn salvage_file(path: &Path) -> Result<SalvageReport> {
    let file = File::open(path).with_context(|| format!("opening stream {path:?}"))?;
    let bytes_total = file.metadata().with_context(|| format!("stat {path:?}"))?.len();
    salvage_reader(file, bytes_total)
}

pub fn salvage_reader<R: Read>(mut src: R, bytes_total: u64) -> Result<SalvageReport> {
    let mut reader = StreamReader::new();
    let mut chunk = [0u8; 64 * 1024];
    let mut report = SalvageReport { bytes_total, ..Default::default() };
    let mut chains = std::collections::BTreeSet::new();
    let mut fed = 0u64;
    'outer: loop {
        let n = src.read(&mut chunk).context("reading stream")?;
        if n == 0 {
            break;
        }
        fed += n as u64;
        reader.feed(&chunk[..n]);
        loop {
            let value = match reader.next_value() {
                None => break,
                Some(Ok(v)) => v,
                Some(Err(e)) => {
                    report.error = Some(e.msg);
                    break 'outer;
                }
            };
            match RunEvent::from_json(&value) {
                Ok(ev) => {
                    report.events += 1;
                    if let RunEvent::Sample { chain, .. } = &ev {
                        chains.insert(*chain);
                        report.samples += 1;
                    }
                    // End of the last intact line (blank lines between
                    // events are part of the intact prefix too).
                    report.bytes_salvaged = fed - reader.buffered() as u64;
                }
                Err(e) => {
                    report.error = Some(format!("line {}: {e:#}", reader.line()));
                    break 'outer;
                }
            }
        }
    }
    if report.error.is_none() {
        // A valid final line missing only its newline is recoverable; a
        // half-written one is the torn tail fsck exists to find.
        match reader.finish() {
            None => report.bytes_salvaged = fed,
            Some(Ok(value)) => match RunEvent::from_json(&value) {
                Ok(ev) => {
                    report.events += 1;
                    if let RunEvent::Sample { chain, .. } = &ev {
                        chains.insert(*chain);
                        report.samples += 1;
                    }
                    report.bytes_salvaged = fed;
                }
                Err(e) => report.error = Some(format!("line {}: {e:#}", reader.line())),
            },
            Some(Err(e)) => report.error = Some(e.msg),
        }
    }
    report.chains = chains.len();
    report.truncated = report.error.is_some() || report.bytes_salvaged < report.bytes_total;
    Ok(report)
}

/// Reconstruct a `RunResult` from a stream file: per-chain samples and
/// Ũ traces, the center trajectory, and the recorded metrics. The
/// result's merged sample view is rebuilt exactly as a live run would.
pub fn replay_file(path: &Path) -> Result<RunResult> {
    let file = File::open(path).with_context(|| format!("opening stream {path:?}"))?;
    replay_reader(file)
}

pub fn replay_reader<R: Read>(src: R) -> Result<RunResult> {
    let mut chains: BTreeMap<usize, ChainTrace> = BTreeMap::new();
    let mut result = RunResult::default();
    scan_stream(src, |event| {
        match event {
            RunEvent::Meta { .. } => {}
            RunEvent::Sample { chain, t, theta } => {
                chain_entry(&mut chains, chain).samples.push((t, theta));
            }
            RunEvent::U { chain, step, t, u } => {
                chain_entry(&mut chains, chain).u_trace.push(TracePoint { step, t, u });
            }
            RunEvent::Center { t, theta } => result.center_trace.push((t, theta)),
            // Membership transitions, checkpoint markers, telemetry
            // frames and health verdicts are run *annotations*: the
            // counters they summarize travel in the metrics event, so
            // reconstruction skips them.
            RunEvent::Member { .. }
            | RunEvent::Checkpoint { .. }
            | RunEvent::Telemetry { .. }
            | RunEvent::Health { .. } => {}
            RunEvent::Metrics { metrics, elapsed } => {
                result.metrics = metrics;
                result.elapsed = elapsed;
            }
        }
        Ok(())
    })?;
    result.chains = chains.into_values().collect();
    result.merge_samples();
    Ok(result)
}

fn chain_entry(chains: &mut BTreeMap<usize, ChainTrace>, chain: usize) -> &mut ChainTrace {
    chains.entry(chain).or_insert_with(|| ChainTrace { worker: chain, ..Default::default() })
}

/// Re-compute convergence diagnostics from a stream *without*
/// reconstructing it: every sample event folds straight into the
/// bounded-memory online accumulator. Returns the summary plus the
/// stream's recorded metrics (if a metrics event was present).
pub fn stream_diag<R: Read>(src: R) -> Result<(OnlineDiagSummary, Option<Metrics>)> {
    let mut diag = OnlineDiag::default();
    let mut metrics = None;
    scan_stream(src, |event| {
        match event {
            RunEvent::Sample { chain, theta, .. } => diag.push(chain, &theta),
            RunEvent::Metrics { metrics: m, .. } => metrics = Some(m),
            _ => {}
        }
        Ok(())
    })?;
    Ok((diag.summary(), metrics))
}

#[cfg(test)]
mod tests {
    use super::*;

    const STREAM: &str = concat!(
        "{\"ev\":\"meta\",\"version\":1,\"scheme\":\"ec\",\"workers\":2,\"seed\":9}\n",
        "{\"ev\":\"u\",\"chain\":0,\"step\":0,\"t\":0.01,\"u\":2.5}\n",
        "{\"ev\":\"sample\",\"chain\":0,\"t\":0.02,\"theta\":[1.5,-0.25]}\n",
        "{\"ev\":\"sample\",\"chain\":1,\"t\":0.015,\"theta\":[0.5,0.75]}\n",
        "{\"ev\":\"center\",\"t\":0.03,\"theta\":[1,0.25]}\n",
        "{\"ev\":\"sample\",\"chain\":0,\"t\":0.04,\"theta\":[null,2]}\n",
        "{\"ev\":\"metrics\",\"total_steps\":200,\"exchanges\":50,\"center_steps\":25,",
        "\"grads_computed\":0,\"steps_per_sec\":1000,\"samples_dropped\":3,",
        "\"mean_staleness\":0,\"elapsed\":0.2}\n",
    );

    #[test]
    fn replay_reconstructs_chains_center_and_metrics() {
        let r = replay_reader(STREAM.as_bytes()).unwrap();
        assert_eq!(r.chains.len(), 2);
        assert_eq!(r.chains[0].worker, 0);
        assert_eq!(r.chains[0].samples.len(), 2);
        assert_eq!(r.chains[0].u_trace.len(), 1);
        assert_eq!(r.chains[1].samples, vec![(0.015, vec![0.5, 0.75])]);
        assert_eq!(r.center_trace, vec![(0.03, vec![1.0, 0.25])]);
        assert_eq!(r.metrics.total_steps, 200);
        assert_eq!(r.metrics.exchanges, 50);
        assert_eq!(r.metrics.center_steps, 25);
        assert_eq!(r.metrics.samples_dropped, 3);
        assert_eq!(r.elapsed, 0.2);
        // Merged view is time-sorted across chains.
        let times: Vec<f64> = r.samples.iter().map(|s| s.0).collect();
        assert_eq!(times, vec![0.015, 0.02, 0.04]);
        // A null θ entry (non-finite at emit time) replays as NaN.
        assert!(r.chains[0].samples[1].1[0].is_nan());
    }

    #[test]
    fn stream_diag_folds_samples_without_reconstruction() {
        let (summary, metrics) = stream_diag(STREAM.as_bytes()).unwrap();
        assert_eq!(summary.chains, 2);
        assert_eq!(summary.n, 3);
        assert_eq!(summary.tracked, 2);
        assert_eq!(metrics.unwrap().total_steps, 200);
    }

    #[test]
    fn unknown_event_kinds_are_rejected() {
        let err = replay_reader("{\"ev\":\"vibes\"}\n".as_bytes()).unwrap_err();
        assert!(format!("{err:#}").contains("vibes"));
    }

    #[test]
    fn malformed_lines_surface_their_line_number() {
        let bad = "{\"ev\":\"meta\"}\n{not json\n";
        let err = replay_reader(bad.as_bytes()).unwrap_err();
        assert!(format!("{err:#}").contains("line 2"), "{err:#}");
    }

    #[test]
    fn events_missing_required_fields_error() {
        assert!(replay_reader("{\"ev\":\"sample\",\"t\":1}\n".as_bytes()).is_err());
        assert!(replay_reader("{\"t\":1}\n".as_bytes()).is_err());
    }

    #[test]
    fn future_stream_versions_are_rejected() {
        let v9 = "{\"ev\":\"meta\",\"version\":9,\"scheme\":\"ec\",\"workers\":1,\"seed\":\"1\"}\n";
        let err = replay_reader(v9.as_bytes()).unwrap_err();
        assert!(format!("{err:#}").contains("version 9"), "{err:#}");
    }

    #[test]
    fn member_and_checkpoint_events_annotate_without_breaking_replay() {
        let stream = concat!(
            "{\"ev\":\"meta\",\"version\":2,\"scheme\":\"ec\",\"workers\":2,\"seed\":\"9\"}\n",
            "{\"ev\":\"sample\",\"chain\":0,\"t\":0.1,\"theta\":[1,2]}\n",
            "{\"ev\":\"member\",\"worker\":1,\"kind\":\"join\",\"t\":0.15}\n",
            "{\"ev\":\"checkpoint\",\"step\":40,\"file\":\"out/ckpt/c.jsonl\"}\n",
            "{\"ev\":\"member\",\"worker\":0,\"kind\":\"fail\",\"t\":0.2}\n",
        );
        let r = replay_reader(stream.as_bytes()).unwrap();
        assert_eq!(r.samples.len(), 1);
        // And the raw events are visible to scan_stream consumers.
        let mut kinds = Vec::new();
        let mut ckpt_steps = Vec::new();
        scan_stream(stream.as_bytes(), |ev| {
            match ev {
                RunEvent::Member { kind, worker, .. } => kinds.push((worker, kind)),
                RunEvent::Checkpoint { step, .. } => ckpt_steps.push(step),
                _ => {}
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(kinds, vec![(1, "join".to_string()), (0, "fail".to_string())]);
        assert_eq!(ckpt_steps, vec![40]);
    }

    #[test]
    fn health_events_annotate_without_breaking_replay() {
        let stream = concat!(
            "{\"ev\":\"meta\",\"version\":4,\"scheme\":\"ec\",\"workers\":2,\"seed\":\"9\"}\n",
            "{\"ev\":\"sample\",\"chain\":0,\"t\":0.1,\"theta\":[1,2]}\n",
            "{\"ev\":\"health\",\"t\":0.2,\"center_steps\":40,\"status\":\"degraded\",",
            "\"workers_active\":1,\"stalled_chains\":[1],\"divergent\":false,",
            "\"theta_norm\":2.5,\"reject_rate\":0,\"ess_per_sec\":null,",
            "\"ess_trend\":0,\"reasons\":[\"chain 1 stalled\"]}\n",
        );
        let r = replay_reader(stream.as_bytes()).unwrap();
        assert_eq!(r.samples.len(), 1);
        let mut statuses = Vec::new();
        scan_stream(stream.as_bytes(), |ev| {
            if let RunEvent::Health { t, json } = ev {
                assert!((t - 0.2).abs() < 1e-12);
                statuses.push(json.get("status").and_then(Json::as_str).unwrap().to_string());
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(statuses, vec!["degraded".to_string()]);
    }

    #[test]
    fn large_seeds_round_trip_through_the_meta_event() {
        let seed = u64::MAX - 12345; // would corrupt through f64
        let line = format!(
            "{{\"ev\":\"meta\",\"version\":1,\"scheme\":\"ec\",\"workers\":2,\"seed\":\"{seed}\"}}\n"
        );
        let v = crate::util::json::Json::parse(line.trim()).unwrap();
        match RunEvent::from_json(&v).unwrap() {
            RunEvent::Meta { seed: parsed, .. } => assert_eq!(parsed, seed),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn non_array_theta_is_rejected_not_emptied() {
        let bad = "{\"ev\":\"sample\",\"chain\":0,\"t\":1,\"theta\":5}\n";
        let err = replay_reader(bad.as_bytes()).unwrap_err();
        assert!(format!("{err:#}").contains("theta"), "{err:#}");
    }

    #[test]
    fn schema_rejections_name_their_line() {
        // Well-formed JSON that is not a valid event must still say
        // which line it sat on (satellite: corrupt-stream forensics).
        let bad = "{\"ev\":\"meta\",\"version\":1}\n{\"ev\":\"sample\",\"t\":1}\n";
        let err = replay_reader(bad.as_bytes()).unwrap_err();
        assert!(format!("{err:#}").contains("line 2"), "{err:#}");
    }

    #[test]
    fn salvage_reports_intact_stream_as_fully_recovered() {
        let r = salvage_reader(STREAM.as_bytes(), STREAM.len() as u64).unwrap();
        assert_eq!(r.events, 7);
        assert_eq!(r.chains, 2);
        assert_eq!(r.samples, 3);
        assert_eq!(r.bytes_salvaged, STREAM.len() as u64);
        assert!(!r.truncated);
        assert!(r.error.is_none());
    }

    #[test]
    fn salvage_finds_last_intact_prefix_of_torn_stream() {
        // Tear the stream mid-way through its final line, like a crash
        // mid-write would.
        let cut = STREAM.len() - 40;
        let torn = &STREAM.as_bytes()[..cut];
        let r = salvage_reader(torn, torn.len() as u64).unwrap();
        // Everything before the torn line is intact…
        let intact_end = STREAM[..cut].rfind('\n').unwrap() + 1;
        assert_eq!(r.bytes_salvaged, intact_end as u64);
        assert_eq!(r.events, 6);
        assert_eq!(r.samples, 3);
        assert!(r.truncated);
        let err = r.error.unwrap();
        assert!(err.contains("line "), "{err}");
        // …and truncating to the salvage point replays cleanly.
        assert!(replay_reader(&STREAM.as_bytes()[..intact_end]).is_ok());
    }

    #[test]
    fn salvage_stops_at_first_damaged_line_mid_stream() {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"{\"ev\":\"meta\",\"version\":1,\"scheme\":\"ec\"}\n");
        let good_end = bytes.len() as u64;
        bytes.extend_from_slice(b"{\"ev\":\"sample\",\"chain\":0,\xFF\xFE garbage\n");
        bytes.extend_from_slice(b"{\"ev\":\"center\",\"t\":1,\"theta\":[0]}\n");
        let total = bytes.len() as u64;
        let r = salvage_reader(&bytes[..], total).unwrap();
        assert_eq!(r.events, 1);
        assert_eq!(r.bytes_salvaged, good_end);
        assert!(r.truncated);
        assert!(r.error.unwrap().contains("line 2"));
    }

    #[test]
    fn salvage_recovers_valid_final_line_missing_its_newline() {
        let s = "{\"ev\":\"meta\",\"version\":1}\n{\"ev\":\"center\",\"t\":1,\"theta\":[0]}";
        let r = salvage_reader(s.as_bytes(), s.len() as u64).unwrap();
        assert_eq!(r.events, 2);
        assert_eq!(r.bytes_salvaged, s.len() as u64);
        assert!(!r.truncated);
        assert!(r.error.is_none());
    }
}
