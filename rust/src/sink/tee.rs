//! Fan-out sink: deliver every event to several sinks.

use super::SampleSink;

/// Delivers each event to all parts in order. The common stack is
/// memory + jsonl + diag: keep a capped in-memory view for immediate
/// reporting, the full stream on disk, and running diagnostics.
pub struct TeeSink {
    parts: Vec<Box<dyn SampleSink>>,
}

impl TeeSink {
    pub fn new(parts: Vec<Box<dyn SampleSink>>) -> TeeSink {
        TeeSink { parts }
    }
}

impl SampleSink for TeeSink {
    fn record(&mut self, t: f64, theta: &[f32]) {
        for p in &mut self.parts {
            p.record(t, theta);
        }
    }

    fn record_u(&mut self, step: usize, t: f64, u: f64) {
        for p in &mut self.parts {
            p.record_u(step, t, u);
        }
    }

    fn record_member(&mut self, t: f64, worker: usize, kind: &str) {
        for p in &mut self.parts {
            p.record_member(t, worker, kind);
        }
    }

    /// A sample counts as dropped only if *every* θ-retaining part
    /// dropped it — a memory part past its cap loses nothing while a
    /// stream part keeps recording, so the tee's loss is the minimum
    /// over retaining parts. Diagnostics-only parts keep no θ by design
    /// and must not mask real loss (their `dropped()` is always 0).
    fn dropped(&self) -> u64 {
        self.parts
            .iter()
            .filter(|p| p.retains_samples())
            .map(|p| p.dropped())
            .min()
            .unwrap_or(0)
    }

    fn retains_samples(&self) -> bool {
        self.parts.iter().any(|p| p.retains_samples())
    }

    /// The retained in-memory view comes from the first part that has
    /// one (the memory part, in the standard stack).
    fn take_samples(&mut self) -> Vec<(f64, Vec<f32>)> {
        for p in &mut self.parts {
            let samples = p.take_samples();
            if !samples.is_empty() {
                return samples;
            }
        }
        Vec::new()
    }

    fn flush(&mut self) {
        for p in &mut self.parts {
            p.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sink::MemorySink;

    #[test]
    fn fans_out_and_takes_from_first_retaining_part() {
        let mut tee =
            TeeSink::new(vec![Box::new(MemorySink::new(1)), Box::new(MemorySink::new(10))]);
        tee.record(0.0, &[1.0]);
        tee.record(1.0, &[2.0]);
        // Part 0 dropped one, part 1 dropped none: nothing is lost.
        assert_eq!(tee.dropped(), 0);
        let kept = tee.take_samples();
        assert_eq!(kept.len(), 1); // the first (capped) part's view
        // Second take falls through to the larger part's retained view.
        assert_eq!(tee.take_samples().len(), 2);
    }

    #[test]
    fn dropped_is_min_over_parts() {
        let mut tee =
            TeeSink::new(vec![Box::new(MemorySink::new(0)), Box::new(MemorySink::new(0))]);
        tee.record(0.0, &[1.0]);
        assert_eq!(tee.dropped(), 1); // every part dropped it: lost
    }

    #[test]
    fn diag_only_parts_do_not_mask_loss() {
        use crate::sink::{Frame, OnlineDiag, OnlineDiagSink};
        use std::sync::{Arc, Mutex};
        let diag = Arc::new(Mutex::new(OnlineDiag::default()));
        let mut tee = TeeSink::new(vec![
            Box::new(MemorySink::new(0)),
            Box::new(OnlineDiagSink::new(diag, Frame::Chain(0))),
        ]);
        tee.record(0.0, &[1.0]);
        // θ is gone (memory full, diag keeps no θ): must count as lost.
        assert_eq!(tee.dropped(), 1);
        assert!(tee.retains_samples());
    }
}
