//! Chrome `chrome://tracing` / Perfetto trace-event exporter
//! (`ecsgmcmc trace --file run.jsonl --out trace.json`).
//!
//! Converts the compact span arrays embedded in a stream's `telemetry`
//! events into the Trace Event JSON format: one `"ph":"X"` (complete)
//! event per span, `ts`/`dur` in microseconds, plus `"M"` metadata
//! events naming each thread row. The conversion is offline and
//! bounded-memory on the input side (one stream line at a time via
//! `scan_stream`); the output trace is buffered per event.

use crate::sink::replay::{scan_stream, RunEvent};
use crate::util::json::{Emitter, Json};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fs::File;
use std::io::Write;
use std::path::Path;

/// Statistics of one conversion, for the CLI summary line.
pub struct TraceStats {
    pub telemetry_events: usize,
    pub spans: usize,
    pub threads: usize,
}

/// Convert `stream` into a Chrome trace file at `out`.
pub fn write_trace(stream: &Path, out: &Path) -> Result<TraceStats> {
    let file = File::open(stream).with_context(|| format!("opening stream {stream:?}"))?;
    let mut labels: BTreeMap<u64, String> = BTreeMap::new();
    let mut spans: Vec<[f64; 4]> = Vec::new(); // [tid, stage, ts_us, dur_us]
    let mut telemetry_events = 0usize;
    scan_stream(file, |ev| {
        let RunEvent::Telemetry { json, .. } = ev else { return Ok(()) };
        telemetry_events += 1;
        if let Some(threads) = json.get("threads").and_then(Json::as_arr) {
            for row in threads {
                let Some(pair) = row.as_arr().and_then(|r| r.get(0..2)) else { continue };
                if let (Some(tid), Some(label)) = (pair[0].as_f64(), pair[1].as_str()) {
                    labels.insert(tid as u64, label.to_string());
                }
            }
        }
        if let Some(rows) = json.get("spans").and_then(Json::as_arr) {
            for row in rows {
                let Some(r) = row.as_arr() else { continue };
                if r.len() < 4 {
                    continue;
                }
                let vals: Vec<f64> = r.iter().filter_map(Json::as_f64).collect();
                if vals.len() == 4 {
                    spans.push([vals[0], vals[1], vals[2], vals[3]]);
                }
            }
        }
        Ok(())
    })?;
    if telemetry_events == 0 {
        bail!(
            "stream {stream:?} has no telemetry events — was the run started \
             with --telemetry (or [telemetry] enabled = true)?"
        );
    }

    let mut e = Emitter::new();
    e.begin_obj();
    e.key("traceEvents").begin_arr();
    for (tid, label) in &labels {
        e.begin_obj();
        e.key("ph").str_val("M");
        e.key("pid").num(1.0);
        e.key("tid").num(*tid as f64);
        e.key("name").str_val("thread_name");
        e.key("args").begin_obj();
        e.key("name").str_val(label);
        e.end_obj();
        e.end_obj();
    }
    for [tid, stage, ts, dur] in &spans {
        let name = super::Stage::from_idx(*stage as u8).map(|s| s.name()).unwrap_or("stage?");
        e.begin_obj();
        e.key("ph").str_val("X");
        e.key("pid").num(1.0);
        e.key("tid").num(*tid);
        e.key("ts").num(*ts);
        e.key("dur").num(*dur);
        e.key("name").str_val(name);
        e.key("cat").str_val("stage");
        e.end_obj();
    }
    e.end_arr();
    e.key("displayTimeUnit").str_val("ms");
    e.end_obj();

    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating trace dir {parent:?}"))?;
        }
    }
    let mut f = File::create(out).with_context(|| format!("creating trace {out:?}"))?;
    f.write_all(e.as_str().as_bytes()).with_context(|| format!("writing trace {out:?}"))?;
    Ok(TraceStats { telemetry_events, spans: spans.len(), threads: labels.len() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converts_telemetry_spans_into_complete_events() {
        let dir = std::env::temp_dir().join("ecsgmcmc-chrome-test");
        std::fs::create_dir_all(&dir).unwrap();
        let stream = dir.join("in.jsonl");
        let out = dir.join("trace.json");
        std::fs::write(
            &stream,
            concat!(
                "{\"ev\":\"meta\",\"version\":3,\"scheme\":\"ec\",\"workers\":1,\"seed\":\"1\"}\n",
                "{\"ev\":\"telemetry\",\"t\":0.1,\"center_steps\":10,\"spans_dropped\":0,",
                "\"threads\":[[0,\"worker-0\"],[1,\"center\"]],",
                "\"spans\":[[0,0,100.5,20.25],[1,2,150,3]]}\n",
            ),
        )
        .unwrap();
        let stats = write_trace(&stream, &out).unwrap();
        assert_eq!(stats.telemetry_events, 1);
        assert_eq!(stats.spans, 2);
        assert_eq!(stats.threads, 2);
        let v = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        let evs = v.get("traceEvents").and_then(Json::as_arr).unwrap();
        // 2 metadata + 2 complete events.
        assert_eq!(evs.len(), 4);
        let x: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .collect();
        assert_eq!(x.len(), 2);
        assert_eq!(x[0].get("name").and_then(Json::as_str), Some("stoch_grad"));
        assert_eq!(x[0].get("ts").and_then(Json::as_f64), Some(100.5));
        assert_eq!(x[1].get("name").and_then(Json::as_str), Some("exchange"));
    }

    #[test]
    fn stream_without_telemetry_events_is_an_error() {
        let dir = std::env::temp_dir().join("ecsgmcmc-chrome-test2");
        std::fs::create_dir_all(&dir).unwrap();
        let stream = dir.join("in.jsonl");
        std::fs::write(
            &stream,
            "{\"ev\":\"meta\",\"version\":3,\"scheme\":\"ec\",\"workers\":1,\"seed\":\"1\"}\n",
        )
        .unwrap();
        let err = write_trace(&stream, &dir.join("t.json")).unwrap_err();
        assert!(format!("{err:#}").contains("telemetry"), "{err:#}");
    }
}
