//! The `telemetry` stream event (stream v3, DESIGN.md §11): the bridge
//! between the in-process [`super::Aggregate`] and the JSONL stream.
//!
//! Schema (schema-additive on stream v2; all keys self-describing):
//!
//! ```json
//! {"ev":"telemetry","t":1.25,"center_steps":400,"spans_dropped":0,
//!  "spans_elided":0,
//!  "stages":{"stoch_grad":{"count":N,"total_ns":S,"p50_ns":..,
//!            "p95_ns":..,"p99_ns":..,"max_ns":..}, ...},
//!  "queue_depth":{"count":..,"p50":..,"p95":..,"p99":..,"max":..},
//!  "staleness":{"mean":..,"p50":..,"p95":..,"p99":..,"max":..},
//!  "counters":{"name":n,...},"gauges":{"name":n,...},
//!  "threads":[[tid,"worker-0"],...],
//!  "spans":[[tid,stage_idx,start_us,dur_us],...]}
//! ```
//!
//! `stages` histograms are cumulative over the run; `spans` is the raw
//! window drained since the previous event (capped at
//! [`super::RECENT_CAP`], overflow counted in `spans_elided`), in
//! microseconds since the emitting process's telemetry epoch. The
//! `staleness` quantiles are computed from the run's *existing*
//! `Metrics::staleness_hist` — the event quotes it rather than keeping a
//! second histogram.

use super::hist::linear_hist_quantile;
use super::{registry_snapshot, thread_labels, Aggregate, SpanEvent, Stage};
use crate::util::json::Emitter;

/// Everything one telemetry event needs, borrowed from the run.
pub struct TelemetryFrame<'a> {
    /// Wall-clock seconds since run start (the stream's `t` convention).
    pub t: f64,
    pub center_steps: u64,
    pub agg: &'a Aggregate,
    /// The run's linear staleness histogram (`Metrics::staleness_hist`).
    pub staleness_hist: &'a [u64],
    /// Raw spans for this event's window (from [`Aggregate::take_recent`]).
    pub spans: &'a [SpanEvent],
    /// Spans that missed the window (histograms still counted them).
    pub spans_elided: u64,
}

impl TelemetryFrame<'_> {
    /// Emit the event as one JSON object (no trailing newline).
    pub fn emit(&self, e: &mut Emitter) {
        e.begin_obj();
        e.key("ev").str_val("telemetry");
        e.key("t").num(self.t);
        e.key("center_steps").num(self.center_steps as f64);
        e.key("spans_dropped").num(self.agg.spans_dropped as f64);
        e.key("spans_elided").num(self.spans_elided as f64);

        e.key("stages").begin_obj();
        for stage in Stage::ALL {
            let h = &self.agg.stages[stage as usize];
            if h.count() == 0 {
                continue;
            }
            e.key(stage.name()).begin_obj();
            e.key("count").num(h.count() as f64);
            e.key("total_ns").num(h.sum() as f64);
            e.key("p50_ns").num(h.quantile(0.50) as f64);
            e.key("p95_ns").num(h.quantile(0.95) as f64);
            e.key("p99_ns").num(h.quantile(0.99) as f64);
            e.key("max_ns").num(h.max() as f64);
            e.end_obj();
        }
        e.end_obj();

        let qd = &self.agg.queue_depth;
        e.key("queue_depth").begin_obj();
        e.key("count").num(qd.count() as f64);
        e.key("p50").num(qd.quantile(0.50) as f64);
        e.key("p95").num(qd.quantile(0.95) as f64);
        e.key("p99").num(qd.quantile(0.99) as f64);
        e.key("max").num(qd.max() as f64);
        e.end_obj();

        let total: u64 = self.staleness_hist.iter().sum();
        let weighted: u64 = self
            .staleness_hist
            .iter()
            .enumerate()
            .map(|(idx, &c)| idx as u64 * c)
            .sum();
        e.key("staleness").begin_obj();
        e.key("count").num(total as f64);
        e.key("mean").num(if total == 0 { 0.0 } else { weighted as f64 / total as f64 });
        e.key("p50").num(linear_hist_quantile(self.staleness_hist, 0.50) as f64);
        e.key("p95").num(linear_hist_quantile(self.staleness_hist, 0.95) as f64);
        e.key("p99").num(linear_hist_quantile(self.staleness_hist, 0.99) as f64);
        let max = self
            .staleness_hist
            .iter()
            .rposition(|&c| c > 0)
            .unwrap_or(0);
        e.key("max").num(max as f64);
        e.end_obj();

        let (counters, gauges) = registry_snapshot();
        e.key("counters").begin_obj();
        for (name, v) in &counters {
            e.key(name).num(*v as f64);
        }
        e.end_obj();
        e.key("gauges").begin_obj();
        for (name, v) in &gauges {
            e.key(name).num(*v as f64);
        }
        e.end_obj();

        e.key("threads").begin_arr();
        for (tid, label) in thread_labels() {
            e.begin_arr();
            e.num(tid as f64);
            e.str_val(&label);
            e.end_arr();
        }
        e.end_arr();

        e.key("spans").begin_arr();
        for s in self.spans {
            e.begin_arr();
            e.num(s.tid as f64);
            e.num(s.stage as f64);
            e.num(s.t_start_ns as f64 / 1_000.0);
            e.num(s.dur_ns as f64 / 1_000.0);
            e.end_arr();
        }
        e.end_arr();

        e.end_obj();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn frame_emits_parseable_self_describing_json() {
        let mut agg = Aggregate::default();
        agg.stages[Stage::StochGrad as usize].record(1_000);
        agg.stages[Stage::StochGrad as usize].record(2_000);
        agg.observe_queue_depth(3);
        let mut staleness = vec![0u64; 65];
        staleness[1] = 10;
        staleness[4] = 2;
        let spans =
            [SpanEvent { tid: 1, stage: 0, t_start_ns: 5_000, dur_ns: 1_000, arg: 0 }];
        let frame = TelemetryFrame {
            t: 0.5,
            center_steps: 40,
            agg: &agg,
            staleness_hist: &staleness,
            spans: &spans,
            spans_elided: 0,
        };
        let mut e = Emitter::new();
        frame.emit(&mut e);
        let v = Json::parse(e.as_str()).unwrap();
        assert_eq!(v.get("ev").and_then(Json::as_str), Some("telemetry"));
        assert_eq!(v.path(&["stages", "stoch_grad", "count"]).and_then(Json::as_f64), Some(2.0));
        let p50 = v.path(&["stages", "stoch_grad", "p50_ns"]).and_then(Json::as_f64);
        assert!(p50.unwrap() >= 1_000.0);
        // Empty stages are elided (schema-additive, not padded).
        assert!(v.path(&["stages", "gemm"]).is_none());
        assert_eq!(v.path(&["staleness", "count"]).and_then(Json::as_f64), Some(12.0));
        assert_eq!(v.path(&["staleness", "p50"]).and_then(Json::as_f64), Some(1.0));
        assert_eq!(v.path(&["staleness", "max"]).and_then(Json::as_f64), Some(4.0));
        assert_eq!(v.path(&["queue_depth", "max"]).and_then(Json::as_f64), Some(3.0));
        let spans = v.get("spans").and_then(Json::as_arr).unwrap();
        assert_eq!(spans.len(), 1);
        let row = spans[0].as_arr().unwrap();
        assert_eq!(row[0].as_f64(), Some(1.0));
        assert_eq!(row[2].as_f64(), Some(5.0)); // 5000 ns = 5 µs
    }
}
