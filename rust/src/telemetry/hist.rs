//! Log-scale histograms and the atomic counter/gauge primitives of the
//! metrics registry.
//!
//! [`LogHist`] is an HdrHistogram-style octave histogram over `u64`
//! values (nanoseconds, queue depths): each power-of-two octave is split
//! into `1 << SUB_BITS` linear sub-buckets, so relative resolution is
//! bounded by `1 / 2^SUB_BITS` (12.5% with the default 3 sub-bits)
//! while the whole `u64` range fits in a few hundred buckets. Quantiles
//! walk the bucket counts and report the bucket's upper bound, so a
//! reported p99 is always ≥ the exact p99 and within one bucket width
//! of it.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

/// Linear sub-buckets per octave (3 → 8 sub-buckets, ≤12.5% error).
const SUB_BITS: u32 = 3;
const SUB: u64 = 1 << SUB_BITS;
/// Octaves above the linear range (values < SUB are exact).
const OCTAVES: u32 = 64 - SUB_BITS;
pub const BUCKETS: usize = (SUB + OCTAVES as u64 * SUB) as usize;

/// Bucket index for a value: exact below `SUB`, then
/// `(octave, sub-bucket)` pairs.
#[inline]
fn bucket_of(v: u64) -> usize {
    if v < SUB {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros(); // >= SUB_BITS
    let octave = msb - SUB_BITS + 1;
    let sub = (v >> (msb - SUB_BITS)) & (SUB - 1);
    (octave as u64 * SUB + sub) as usize
}

/// Inclusive upper bound of a bucket — what quantiles report.
fn bucket_upper(idx: usize) -> u64 {
    let idx = idx as u64;
    if idx < SUB {
        return idx;
    }
    let octave = (idx / SUB) as u32; // >= 1
    let sub = idx % SUB;
    let base = 1u64 << (octave - 1 + SUB_BITS);
    let width = base >> SUB_BITS;
    base + (sub + 1) * width - 1
}

/// Plain (single-thread) log-scale histogram: the drain-time fold.
#[derive(Clone)]
pub struct LogHist {
    counts: Vec<u64>,
    count: u64,
    sum: u64,
    max: u64,
}

impl Default for LogHist {
    fn default() -> Self {
        LogHist { counts: vec![0; BUCKETS], count: 0, sum: 0, max: 0 }
    }
}

impl LogHist {
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_of(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Value at quantile `q` in `[0, 1]`: the upper bound of the first
    /// bucket whose cumulative count reaches `ceil(q · n)`. 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_upper(idx).min(self.max);
            }
        }
        self.max
    }
}

/// Monotone atomic counter (events, bytes, drops).
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins atomic gauge (queue depth, live fleet size).
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Quantiles over the run's *linear* staleness histogram
/// (`Metrics::staleness_hist`, 65 clamped buckets): the telemetry event
/// quotes the existing histogram instead of keeping a duplicate.
pub fn linear_hist_quantile(counts: &[u64], q: f64) -> u64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0;
    }
    let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
    let mut seen = 0u64;
    for (idx, &c) in counts.iter().enumerate() {
        seen += c;
        if seen >= rank {
            return idx as u64;
        }
    }
    counts.len() as u64 - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_are_exact() {
        let mut h = LogHist::default();
        for v in 0..SUB {
            h.record(v);
        }
        assert_eq!(h.quantile(0.0), 0);
        assert_eq!(h.quantile(1.0), SUB - 1);
        assert_eq!(h.count(), SUB);
    }

    #[test]
    fn bucket_bounds_cover_u64_monotonically() {
        let mut prev = 0;
        for idx in 1..BUCKETS {
            let hi = bucket_upper(idx);
            assert!(hi > prev, "bucket {idx}: {hi} <= {prev}");
            prev = hi;
        }
        // Every value lands in a bucket whose upper bound is >= it and
        // within the 12.5% relative-resolution contract.
        for v in [1u64, 7, 8, 9, 100, 1_000, 123_456, u32::MAX as u64, u64::MAX / 3] {
            let hi = bucket_upper(bucket_of(v));
            assert!(hi >= v);
            assert!((hi - v) as f64 <= v as f64 / SUB as f64 + 1.0, "v={v} hi={hi}");
        }
    }

    #[test]
    fn quantiles_match_exact_reference_within_resolution() {
        // Uniform 1..=10_000: exact pXX is XX% of 10_000.
        let mut h = LogHist::default();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        for (q, exact) in [(0.50, 5_000u64), (0.95, 9_500), (0.99, 9_900)] {
            let got = h.quantile(q);
            assert!(got >= exact, "q={q}: {got} < exact {exact}");
            let rel = (got - exact) as f64 / exact as f64;
            assert!(rel <= 1.0 / SUB as f64 + 1e-9, "q={q}: rel error {rel}");
        }
        assert_eq!(h.quantile(1.0), 10_000);
        assert_eq!(h.max(), 10_000);
        assert!((h.mean() - 5_000.5).abs() < 1e-9);
    }

    #[test]
    fn empty_hist_is_all_zeros() {
        let h = LogHist::default();
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.max(), 0);
    }

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::default();
        c.add(3);
        c.add(4);
        assert_eq!(c.get(), 7);
        let g = Gauge::default();
        g.set(42);
        g.set(-1);
        assert_eq!(g.get(), -1);
    }

    #[test]
    fn linear_quantile_walks_the_staleness_buckets() {
        let mut counts = vec![0u64; 65];
        counts[0] = 50;
        counts[2] = 40;
        counts[10] = 10;
        assert_eq!(linear_hist_quantile(&counts, 0.5), 0);
        assert_eq!(linear_hist_quantile(&counts, 0.9), 2);
        assert_eq!(linear_hist_quantile(&counts, 0.99), 10);
        assert_eq!(linear_hist_quantile(&[0u64; 65], 0.5), 0);
    }
}
