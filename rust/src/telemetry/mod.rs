//! Telemetry subsystem (DESIGN.md §11): lock-free span tracing, a
//! metrics registry, and the export surfaces behind `ecsgmcmc trace` /
//! `ecsgmcmc top`.
//!
//! Recording is built around per-thread SPSC rings ([`ring::Ring`]):
//! an instrumented stage opens a [`SpanGuard`] (`span(Stage::StochGrad)`)
//! and the guard's drop pushes one fixed-size [`ring::SpanEvent`] into
//! the calling thread's ring — no allocation, no lock, no syscall on the
//! hot path. The coordinator periodically drains every ring into an
//! [`Aggregate`] (per-stage log-scale histograms + a capped raw-span
//! window) and emits one schema-additive `telemetry` stream event.
//!
//! **Overhead contract.** Telemetry is *disabled* by default and the
//! disabled path of every instrumented site is exactly one relaxed
//! atomic load and one predictable branch — no clock read, no ring
//! write. That is what "compiled out of the step loop" means here: the
//! check itself stays (a runtime toggle, like the kernel-dispatch mode),
//! but nothing observable happens behind it, so bit-exactness contracts
//! and the PR 5 kernel benchmarks are untouched. Enabled-mode overhead
//! is gated <3% on step throughput (`bench/BENCH_telemetry.json`).
//!
//! Sampling dynamics never observe telemetry state: spans read the
//! monotonic clock only, never the RNG streams, so an enabled run's
//! samples are bit-identical to a disabled run's (asserted in
//! `tests/test_telemetry.rs`).

pub mod chrome;
pub mod event;
pub mod hist;
pub mod ring;
pub mod top;

pub use hist::{Counter, Gauge, LogHist};
pub use ring::{Ring, SpanEvent};

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Instrumented pipeline stages — compile-time-known names, one byte on
/// the wire. Extend by appending (indices are stable in streams).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Stochastic-gradient evaluation (single or batched).
    StochGrad = 0,
    /// A dispatched GEMM kernel call (the Fig. 2 NN layer family).
    Gemm = 1,
    /// Worker↔center exchange round trip.
    Exchange = 2,
    /// Durable snapshot write (tmp + fsync + rename).
    CheckpointWrite = 3,
    /// JSONL stream flush.
    SinkFlush = 4,
}

impl Stage {
    pub const ALL: [Stage; 5] = [
        Stage::StochGrad,
        Stage::Gemm,
        Stage::Exchange,
        Stage::CheckpointWrite,
        Stage::SinkFlush,
    ];
    pub const COUNT: usize = Self::ALL.len();

    pub fn name(&self) -> &'static str {
        match self {
            Stage::StochGrad => "stoch_grad",
            Stage::Gemm => "gemm",
            Stage::Exchange => "exchange",
            Stage::CheckpointWrite => "checkpoint_write",
            Stage::SinkFlush => "sink_flush",
        }
    }

    pub fn from_idx(idx: u8) -> Option<Stage> {
        Stage::ALL.get(idx as usize).copied()
    }
}

// ---------------------------------------------------------------------
// Process-global switches (the `math/simd.rs` MODE pattern: settable
// mid-process so one bench process can measure off-then-on).
// ---------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
static EVERY: AtomicU64 = AtomicU64::new(50);
static RING_CAP: AtomicUsize = AtomicUsize::new(4096);

/// Is span recording on? The *entire* disabled-path cost of an
/// instrumented site: one relaxed load + branch.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Center steps between periodic telemetry events.
pub fn every() -> u64 {
    EVERY.load(Ordering::Relaxed).max(1)
}

/// Per-thread ring capacity (rounded up to a power of two at ring
/// creation); applies to threads instrumented *after* the call.
pub fn ring_capacity() -> usize {
    RING_CAP.load(Ordering::Relaxed)
}

/// One-shot configuration from config/CLI (`[telemetry]`,
/// `--telemetry`/`--telemetry-every`).
pub fn configure(enabled: bool, every: u64, ring_capacity: usize) {
    EVERY.store(every.max(1), Ordering::Relaxed);
    RING_CAP.store(ring_capacity.max(2), Ordering::Relaxed);
    ENABLED.store(enabled, Ordering::Relaxed);
}

/// Process-start epoch for span timestamps: monotonic, shared by every
/// thread so cross-thread spans are directly comparable.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the process telemetry epoch.
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

// ---------------------------------------------------------------------
// Per-thread recorders
// ---------------------------------------------------------------------

struct ThreadEntry {
    tid: u16,
    ring: Arc<Ring>,
}

/// All registered rings plus human labels. Locked only at thread
/// registration, label updates and drains — never on the span path.
struct Registry {
    threads: Mutex<Vec<ThreadEntry>>,
    labels: Mutex<BTreeMap<u16, String>>,
}

fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(|| Registry {
        threads: Mutex::new(Vec::new()),
        labels: Mutex::new(BTreeMap::new()),
    })
}

thread_local! {
    static LOCAL: RefCell<Option<(u16, Arc<Ring>)>> = const { RefCell::new(None) };
}

/// This thread's (tid, ring), registering it on first use.
fn local_ring() -> (u16, Arc<Ring>) {
    LOCAL.with(|cell| {
        let mut slot = cell.borrow_mut();
        if let Some((tid, ring)) = slot.as_ref() {
            return (*tid, ring.clone());
        }
        let reg = registry();
        let mut threads = reg.threads.lock().unwrap();
        let tid = threads.len().min(u16::MAX as usize) as u16;
        let ring = Arc::new(Ring::new(ring_capacity()));
        threads.push(ThreadEntry { tid, ring: ring.clone() });
        drop(threads);
        let name = std::thread::current().name().map(str::to_string);
        let label = name.unwrap_or_else(|| format!("thread-{tid}"));
        reg.labels.lock().unwrap().insert(tid, label);
        *slot = Some((tid, ring.clone()));
        (tid, ring)
    })
}

/// Attach a human label ("worker-3", "center") to the calling thread for
/// trace/`top` rendering. No-op while telemetry is disabled.
pub fn set_thread_label(label: &str) {
    if !enabled() {
        return;
    }
    let (tid, _) = local_ring();
    registry().labels.lock().unwrap().insert(tid, label.to_string());
}

/// Snapshot of `(tid, label)` pairs for every registered thread.
pub fn thread_labels() -> Vec<(u16, String)> {
    registry().labels.lock().unwrap().iter().map(|(t, l)| (*t, l.clone())).collect()
}

// ---------------------------------------------------------------------
// Span guards
// ---------------------------------------------------------------------

/// RAII span: records `{thread, stage, t_start_ns, dur_ns, arg}` into
/// the thread's ring when dropped. Inert (no clock read, no ring access)
/// when telemetry is disabled at open time.
pub struct SpanGuard {
    start_ns: u64,
    stage: Stage,
    arg: u64,
    active: bool,
}

/// Open a span over `stage`.
#[inline]
pub fn span(stage: Stage) -> SpanGuard {
    span_arg(stage, 0)
}

/// Open a span carrying a stage-specific argument (batch size, bytes).
#[inline]
pub fn span_arg(stage: Stage, arg: u64) -> SpanGuard {
    if !enabled() {
        return SpanGuard { start_ns: 0, stage, arg: 0, active: false };
    }
    SpanGuard { start_ns: now_ns(), stage, arg, active: true }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let end = now_ns();
        let (tid, ring) = local_ring();
        ring.push(SpanEvent {
            tid,
            stage: self.stage as u8,
            t_start_ns: self.start_ns,
            dur_ns: end.saturating_sub(self.start_ns),
            arg: self.arg,
        });
    }
}

// ---------------------------------------------------------------------
// Metrics registry: named atomic counters/gauges
// ---------------------------------------------------------------------

struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
}

fn metrics_registry() -> &'static MetricsRegistry {
    static METRICS: OnceLock<MetricsRegistry> = OnceLock::new();
    METRICS.get_or_init(|| MetricsRegistry {
        counters: Mutex::new(BTreeMap::new()),
        gauges: Mutex::new(BTreeMap::new()),
    })
}

/// Canonicalize a metric name to the Prometheus exposition charset
/// (`[a-zA-Z_:][a-zA-Z0-9_:]*`): every illegal character becomes `_`
/// and a leading digit gains a `_` prefix. Idempotent, and applied at
/// the registry boundary — free-form callers (fault points like
/// `faults.ckpt`, thread-derived labels) can use any name and every
/// name that reaches `/metrics` or a telemetry frame is legal by
/// construction. Aliasing is the contract: `faults.ckpt` and
/// `faults_ckpt` are the same counter.
pub fn sanitize_metric_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 1);
    for ch in name.chars() {
        if out.is_empty() && ch.is_ascii_digit() {
            out.push('_');
        }
        if ch.is_ascii_alphanumeric() || ch == '_' || ch == ':' {
            out.push(ch);
        } else {
            out.push('_');
        }
    }
    if out.is_empty() {
        out.push('_');
    }
    out
}

/// Get-or-create the named counter (name sanitized — see
/// [`sanitize_metric_name`]). Callers cache the `Arc` (the lookup
/// locks); `Counter::add` itself is a relaxed atomic.
pub fn counter(name: &str) -> Arc<Counter> {
    let reg = metrics_registry();
    let mut counters = reg.counters.lock().unwrap();
    counters.entry(sanitize_metric_name(name)).or_default().clone()
}

/// Get-or-create the named gauge (name sanitized like [`counter`]).
pub fn gauge(name: &str) -> Arc<Gauge> {
    let reg = metrics_registry();
    let mut gauges = reg.gauges.lock().unwrap();
    gauges.entry(sanitize_metric_name(name)).or_default().clone()
}

/// Snapshot every registered counter and gauge for the telemetry event.
pub fn registry_snapshot() -> (Vec<(String, u64)>, Vec<(String, i64)>) {
    let reg = metrics_registry();
    let counters =
        reg.counters.lock().unwrap().iter().map(|(k, v)| (k.clone(), v.get())).collect();
    let gauges = reg.gauges.lock().unwrap().iter().map(|(k, v)| (k.clone(), v.get())).collect();
    (counters, gauges)
}

// ---------------------------------------------------------------------
// Draining and aggregation
// ---------------------------------------------------------------------

/// Raw spans retained per drain window for the stream's compact span
/// list (and thence the Chrome trace). Overflow is counted, not lost —
/// histograms always see every span.
pub const RECENT_CAP: usize = 2048;

/// Fold target for drained spans: cumulative per-stage latency
/// histograms, the queue-depth distribution, and a bounded window of
/// raw spans for the next telemetry event.
pub struct Aggregate {
    /// One log-scale duration histogram per [`Stage`] (cumulative).
    pub stages: Vec<LogHist>,
    /// Center recv batch sizes / transport queue depths (cumulative).
    pub queue_depth: LogHist,
    /// Ring-full drops across all threads (cumulative snapshot).
    pub spans_dropped: u64,
    /// Raw spans since the last [`Aggregate::take_recent`], capped at
    /// [`RECENT_CAP`].
    pub recent: Vec<SpanEvent>,
    /// Spans that missed the `recent` window this interval (histograms
    /// still counted them).
    pub recent_overflow: u64,
}

impl Default for Aggregate {
    fn default() -> Self {
        Aggregate {
            stages: vec![LogHist::default(); Stage::COUNT],
            queue_depth: LogHist::default(),
            spans_dropped: 0,
            recent: Vec::new(),
            recent_overflow: 0,
        }
    }
}

impl Aggregate {
    fn fold(&mut self, ev: SpanEvent) {
        if let Some(h) = self.stages.get_mut(ev.stage as usize) {
            h.record(ev.dur_ns);
        }
        if self.recent.len() < RECENT_CAP {
            self.recent.push(ev);
        } else {
            self.recent_overflow += 1;
        }
    }

    /// Record one observed transport queue depth (recv batch size).
    pub fn observe_queue_depth(&mut self, depth: u64) {
        self.queue_depth.record(depth);
    }

    /// Drain the raw-span window for one telemetry event.
    pub fn take_recent(&mut self) -> (Vec<SpanEvent>, u64) {
        let overflow = self.recent_overflow;
        self.recent_overflow = 0;
        (std::mem::take(&mut self.recent), overflow)
    }

    /// Total recorded spans across all stages.
    pub fn total_spans(&self) -> u64 {
        self.stages.iter().map(LogHist::count).sum()
    }
}

/// Drain every registered ring into `agg`. Serialized by an internal
/// lock: the SPSC rings tolerate exactly one consumer at a time (the
/// center server during segments, the driver after it joins).
pub fn drain_into(agg: &mut Aggregate) {
    static DRAIN: Mutex<()> = Mutex::new(());
    let _guard = DRAIN.lock().unwrap();
    let rings: Vec<Arc<Ring>> =
        registry().threads.lock().unwrap().iter().map(|e| e.ring.clone()).collect();
    let mut dropped = 0;
    for ring in &rings {
        while let Some(ev) = ring.pop() {
            agg.fold(ev);
        }
        dropped += ring.dropped();
    }
    agg.spans_dropped = dropped;
}

/// Drain and discard everything recorded so far — called at run start so
/// a run's first telemetry event never carries a previous run's spans.
pub fn discard_pending() {
    let mut scratch = Aggregate::default();
    drain_into(&mut scratch);
}

#[cfg(test)]
mod tests {
    use super::*;

    // Global-state tests share the process-wide toggle; serialize them.
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_spans_record_nothing() {
        let _l = LOCK.lock().unwrap();
        set_enabled(false);
        discard_pending();
        {
            let _s = span(Stage::StochGrad);
        }
        let mut agg = Aggregate::default();
        drain_into(&mut agg);
        assert_eq!(agg.total_spans(), 0);
    }

    #[test]
    fn enabled_spans_fold_into_their_stage() {
        let _l = LOCK.lock().unwrap();
        set_enabled(true);
        discard_pending();
        {
            let _s = span_arg(Stage::Exchange, 7);
        }
        {
            let _s = span(Stage::StochGrad);
        }
        set_enabled(false);
        let mut agg = Aggregate::default();
        drain_into(&mut agg);
        assert_eq!(agg.stages[Stage::Exchange as usize].count(), 1);
        assert_eq!(agg.stages[Stage::StochGrad as usize].count(), 1);
        let (recent, overflow) = agg.take_recent();
        assert_eq!(overflow, 0);
        assert!(recent.iter().any(|e| e.stage == Stage::Exchange as u8 && e.arg == 7));
    }

    #[test]
    fn stage_names_round_trip_indices() {
        for (i, s) in Stage::ALL.iter().enumerate() {
            assert_eq!(*s as usize, i);
            assert_eq!(Stage::from_idx(i as u8), Some(*s));
        }
        assert_eq!(Stage::from_idx(200), None);
    }

    #[test]
    fn counters_and_gauges_are_shared_by_name() {
        // Registry names are sanitized at the boundary, so the dotted
        // spelling and the canonical spelling alias the same counter.
        let a = counter("test.uploads");
        let b = counter("test_uploads");
        a.add(2);
        b.add(3);
        assert_eq!(counter("test.uploads").get(), 5);
        gauge("test.depth").set(9);
        let (cs, gs) = registry_snapshot();
        assert!(cs.iter().any(|(k, v)| k == "test_uploads" && *v == 5));
        assert!(cs.iter().all(|(k, _)| !k.contains('.')), "snapshot names must be sanitized");
        assert!(gs.iter().any(|(k, v)| k == "test_depth" && *v == 9));
    }

    #[test]
    fn sanitize_legalizes_and_round_trips() {
        for (raw, want) in [
            ("faults.ckpt", "faults_ckpt"),
            ("ec-worker-3", "ec_worker_3"),
            ("stage p99 (ns)", "stage_p99__ns_"),
            ("9lives", "_9lives"),
            ("", "_"),
            ("already_legal:total", "already_legal:total"),
            ("héllo", "h_llo"),
        ] {
            let got = sanitize_metric_name(raw);
            assert_eq!(got, want, "sanitize({raw:?})");
            // Idempotent: a sanitized name survives re-sanitization, so
            // reads and writes through the registry always alias.
            assert_eq!(sanitize_metric_name(&got), got);
            // The result is exposition-legal.
            let mut chars = got.chars();
            let first = chars.next().unwrap();
            assert!(first.is_ascii_alphabetic() || first == '_' || first == ':');
            assert!(chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'));
        }
    }

    #[test]
    fn configure_round_trips() {
        let _l = LOCK.lock().unwrap();
        configure(false, 25, 100);
        assert!(!enabled());
        assert_eq!(every(), 25);
        assert_eq!(ring_capacity(), 100);
        configure(false, 0, 0);
        assert_eq!(every(), 1); // degenerate values clamp
        assert_eq!(ring_capacity(), 2);
        configure(false, 50, 4096);
    }
}
