//! Fixed-capacity single-producer/single-consumer span ring.
//!
//! One ring per instrumented thread (the producer); the coordinator
//! drains them all (the consumer). The hot path — [`Ring::push`] — does
//! no allocation and takes no lock: one relaxed head load, one acquire
//! tail load, one slot write, one release head store. When the ring is
//! full the *newest* span is dropped and counted ([`Ring::dropped`]),
//! never silently lost: the drain folds the counter into the telemetry
//! event so a saturated ring is visible in the stream.
//!
//! Reader hand-off: during a run the center server drains; after the
//! server thread joins, the driver takes over for the final drain. The
//! thread join orders those two readers, so the tail needs no stronger
//! ordering than release/acquire.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, Ordering};

/// One recorded span: `{thread, span, t_start_ns, dur_ns, args}` packed
/// into five words. `stage` indexes [`super::Stage`]; `arg` is a
/// stage-specific payload (batch size for gradient spans, bytes for
/// checkpoint writes, 0 otherwise).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SpanEvent {
    pub tid: u16,
    pub stage: u8,
    pub t_start_ns: u64,
    pub dur_ns: u64,
    pub arg: u64,
}

/// SPSC ring of [`SpanEvent`]s. Capacity is rounded up to a power of two
/// so the index mask is one AND.
pub struct Ring {
    mask: u64,
    /// Next write position; owned by the producer, release-published.
    head: AtomicU64,
    /// Next read position; owned by the (current) consumer.
    tail: AtomicU64,
    /// Spans rejected because the ring was full.
    dropped: AtomicU64,
    slots: Box<[UnsafeCell<SpanEvent>]>,
}

// Slots are plain-old-data guarded by the head/tail protocol: the
// producer only writes slots in `[tail+cap, head]`-free space it
// published last, the consumer only reads slots below the acquired head.
unsafe impl Sync for Ring {}
unsafe impl Send for Ring {}

impl Ring {
    pub fn new(capacity: usize) -> Ring {
        let cap = capacity.max(2).next_power_of_two() as u64;
        let slots = (0..cap).map(|_| UnsafeCell::new(SpanEvent::default())).collect();
        Ring {
            mask: cap - 1,
            head: AtomicU64::new(0),
            tail: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            slots,
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Producer side: record one span, or count it dropped if the
    /// consumer has fallen a full ring behind.
    #[inline]
    pub fn push(&self, ev: SpanEvent) -> bool {
        let head = self.head.load(Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Acquire);
        if head - tail >= self.slots.len() as u64 {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        unsafe { *self.slots[(head & self.mask) as usize].get() = ev };
        self.head.store(head + 1, Ordering::Release);
        true
    }

    /// Consumer side: take the oldest recorded span, if any.
    pub fn pop(&self) -> Option<SpanEvent> {
        let tail = self.tail.load(Ordering::Relaxed);
        let head = self.head.load(Ordering::Acquire);
        if tail == head {
            return None;
        }
        let ev = unsafe { *self.slots[(tail & self.mask) as usize].get() };
        self.tail.store(tail + 1, Ordering::Release);
        Some(ev)
    }

    /// Spans rejected so far because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(stage: u8, start: u64) -> SpanEvent {
        SpanEvent { tid: 1, stage, t_start_ns: start, dur_ns: 10, arg: 0 }
    }

    #[test]
    fn capacity_rounds_up_to_power_of_two() {
        assert_eq!(Ring::new(1).capacity(), 2);
        assert_eq!(Ring::new(5).capacity(), 8);
        assert_eq!(Ring::new(64).capacity(), 64);
    }

    #[test]
    fn fifo_round_trip() {
        let r = Ring::new(4);
        for i in 0..3 {
            assert!(r.push(ev(0, i)));
        }
        for i in 0..3 {
            assert_eq!(r.pop().unwrap().t_start_ns, i);
        }
        assert!(r.pop().is_none());
    }

    #[test]
    fn wraparound_drops_are_counted_never_silent() {
        let r = Ring::new(8); // capacity 8
        for i in 0..20 {
            r.push(ev(0, i));
        }
        // The first 8 spans survive (drop-newest), the other 12 are
        // counted — total offered always equals kept + dropped.
        let mut kept = Vec::new();
        while let Some(e) = r.pop() {
            kept.push(e.t_start_ns);
        }
        assert_eq!(kept, (0..8).collect::<Vec<u64>>());
        assert_eq!(r.dropped(), 12);
        assert_eq!(kept.len() as u64 + r.dropped(), 20);
    }

    #[test]
    fn drain_reopens_space() {
        let r = Ring::new(2);
        assert!(r.push(ev(0, 0)));
        assert!(r.push(ev(0, 1)));
        assert!(!r.push(ev(0, 2)));
        assert_eq!(r.pop().unwrap().t_start_ns, 0);
        assert!(r.push(ev(0, 3)));
        assert_eq!(r.pop().unwrap().t_start_ns, 1);
        assert_eq!(r.pop().unwrap().t_start_ns, 3);
        assert_eq!(r.dropped(), 1);
    }

    #[test]
    fn cross_thread_hand_off() {
        use std::sync::Arc;
        let r = Arc::new(Ring::new(1024));
        let w = r.clone();
        std::thread::spawn(move || {
            for i in 0..100 {
                w.push(ev(2, i));
            }
        })
        .join()
        .unwrap();
        let mut n = 0;
        while let Some(e) = r.pop() {
            assert_eq!(e.t_start_ns, n);
            n += 1;
        }
        assert_eq!(n, 100);
    }
}
