//! `ecsgmcmc top --file <stream>`: live run introspection from a JSONL
//! stream.
//!
//! Tails the stream with bounded memory (`StreamReader` over appended
//! bytes only), folding events into a [`TopState`]: per-worker step
//! rates from `u` events, the stage time breakdown / staleness /
//! queue-depth quantiles from the newest `telemetry` event (whose
//! histograms are cumulative), and R̂/ESS by pushing every `sample`
//! event through the same `OnlineDiag` accumulator live runs use.

use crate::sink::diag::OnlineDiag;
use crate::sink::replay::RunEvent;
use crate::util::json::{Json, StreamReader};
use crate::util::timer::human_duration_secs;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::Path;

#[derive(Default)]
struct ChainStat {
    steps: usize,
    last_t: f64,
    samples: u64,
}

/// Bounded-memory fold of a run stream for the `top` display.
#[derive(Default)]
pub struct TopState {
    scheme: String,
    workers: usize,
    chains: BTreeMap<usize, ChainStat>,
    diag: OnlineDiag,
    last_telemetry: Option<Json>,
    /// Newest health verdict (stream v4), shown verbatim in the header.
    last_health: Option<Json>,
    /// Set once the stream's end-of-run metrics event arrives.
    pub finished: bool,
    events: u64,
    /// Lines the tail could not decode (damage survives follow mode).
    damaged: u64,
    first_damage: Option<String>,
}

impl TopState {
    pub fn fold(&mut self, ev: &RunEvent, raw: &Json) {
        self.events += 1;
        match ev {
            RunEvent::Meta { scheme, workers, .. } => {
                self.scheme = scheme.clone();
                self.workers = *workers;
            }
            RunEvent::U { chain, step, t, .. } => {
                let c = self.chains.entry(*chain).or_default();
                // Saturating: a corrupt stream can carry step = usize::MAX.
                c.steps = c.steps.max(step.saturating_add(1));
                c.last_t = c.last_t.max(*t);
            }
            RunEvent::Sample { chain, theta, t } => {
                let c = self.chains.entry(*chain).or_default();
                c.samples += 1;
                c.last_t = c.last_t.max(*t);
                self.diag.push(*chain, theta);
            }
            RunEvent::Telemetry { .. } => self.last_telemetry = Some(raw.clone()),
            RunEvent::Health { .. } => self.last_health = Some(raw.clone()),
            RunEvent::Metrics { .. } => self.finished = true,
            _ => {}
        }
    }

    /// Record a line the tail could not decode. `top --follow` keeps
    /// tailing across damage (a torn write mid-follow must not kill the
    /// dashboard); the damage stays visible on the screen instead.
    pub fn note_damage(&mut self, line: usize, msg: &str) {
        self.damaged += 1;
        if self.first_damage.is_none() {
            self.first_damage = Some(format!("line {line}: {msg}"));
        }
    }

    /// Render the current state as the `top` screen.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let push = |out: &mut String, s: String| {
            out.push_str(&s);
            out.push('\n');
        };
        push(
            &mut out,
            format!(
                "ecsgmcmc top — scheme {}, {} workers, {} events{}",
                if self.scheme.is_empty() { "?" } else { &self.scheme },
                self.workers,
                self.events,
                if self.finished { " (run finished)" } else { "" }
            ),
        );

        push(&mut out, format!("{:<7} {:>9} {:>10} {:>9}", "chain", "steps", "steps/s", "samples"));
        for (id, c) in &self.chains {
            let rate = if c.last_t > 0.0 { c.steps as f64 / c.last_t } else { 0.0 };
            push(&mut out, format!("{id:<7} {:>9} {rate:>10.1} {:>9}", c.steps, c.samples));
        }

        if let Some(h) = &self.last_health {
            let status = h.get("status").and_then(Json::as_str).unwrap_or("?");
            let active = h.get("workers_active").and_then(Json::as_usize).unwrap_or(0);
            let stalled =
                h.get("stalled_chains").and_then(Json::as_arr).map_or(0, |a| a.len());
            let reasons = h
                .get("reasons")
                .and_then(Json::as_arr)
                .map(|arr| {
                    arr.iter().filter_map(Json::as_str).collect::<Vec<_>>().join("; ")
                })
                .unwrap_or_default();
            push(
                &mut out,
                format!(
                    "health: {status} — {active} active, {stalled} stalled{}{}",
                    if reasons.is_empty() { "" } else { " — " },
                    reasons
                ),
            );
        }

        if let Some(t) = &self.last_telemetry {
            if let Some(stages) = t.get("stages").and_then(Json::as_obj) {
                push(
                    &mut out,
                    format!(
                        "{:<17} {:>9} {:>9} {:>9} {:>9} {:>10}",
                        "stage", "count", "p50", "p95", "p99", "total"
                    ),
                );
                for (name, s) in stages {
                    let num = |k| s.get(k).and_then(Json::as_f64).unwrap_or(0.0);
                    push(
                        &mut out,
                        format!(
                            "{name:<17} {:>9} {:>9} {:>9} {:>9} {:>10}",
                            num("count") as u64,
                            human_duration_secs(num("p50_ns") / 1e9),
                            human_duration_secs(num("p95_ns") / 1e9),
                            human_duration_secs(num("p99_ns") / 1e9),
                            human_duration_secs(num("total_ns") / 1e9),
                        ),
                    );
                }
            }
            if let Some(st) = t.get("staleness") {
                let num = |k| st.get(k).and_then(Json::as_f64).unwrap_or(0.0);
                push(
                    &mut out,
                    format!(
                        "staleness: mean {:.2}  p50 {}  p95 {}  p99 {}  max {}",
                        num("mean"),
                        num("p50") as u64,
                        num("p95") as u64,
                        num("p99") as u64,
                        num("max") as u64
                    ),
                );
            }
            if let Some(qd) = t.get("queue_depth") {
                let num = |k| qd.get(k).and_then(Json::as_f64).unwrap_or(0.0);
                push(
                    &mut out,
                    format!(
                        "queue depth: p50 {}  p95 {}  p99 {}  max {}",
                        num("p50") as u64,
                        num("p95") as u64,
                        num("p99") as u64,
                        num("max") as u64
                    ),
                );
            }
            let dropped = t.get("spans_dropped").and_then(Json::as_f64).unwrap_or(0.0);
            if dropped > 0.0 {
                push(&mut out, format!("spans dropped (ring full): {}", dropped as u64));
            }
        } else {
            push(&mut out, "no telemetry events yet (run started with --telemetry?)".into());
        }

        let d = self.diag.summary();
        if d.n > 0 {
            push(
                &mut out,
                format!(
                    "diag: n={} chains={} max R-hat={:.4} min ESS={:.1}",
                    d.n, d.chains, d.max_rhat, d.min_ess
                ),
            );
        }
        if self.damaged > 0 {
            push(
                &mut out,
                format!(
                    "stream damage: {} undecodable line(s), first at {}",
                    self.damaged,
                    self.first_damage.as_deref().unwrap_or("?")
                ),
            );
        }
        out
    }
}

/// Incremental tail over a stream file: remembers the byte offset and
/// line-framing state across polls, so each call folds only appended
/// bytes.
pub struct StreamTail {
    offset: u64,
    reader: StreamReader,
}

impl Default for StreamTail {
    fn default() -> Self {
        StreamTail { offset: 0, reader: StreamReader::new() }
    }
}

impl StreamTail {
    /// Read everything appended since the last poll into `state`.
    /// Returns the number of events folded.
    ///
    /// Damage tolerance (`--follow` must survive what `fsck` merely
    /// reports): an undecodable line — torn write, corrupt bytes,
    /// schema-invalid event — is counted via [`TopState::note_damage`]
    /// and skipped, and the tail keeps folding subsequent lines. A
    /// partially-appended final line is not damage: its bytes stay
    /// buffered in the framing reader until the writer finishes it. A
    /// file that *shrank* below our offset (a resumed run truncating
    /// post-checkpoint events) restarts the fold from scratch.
    pub fn poll(&mut self, path: &Path, state: &mut TopState) -> Result<usize> {
        let mut file = File::open(path).with_context(|| format!("opening stream {path:?}"))?;
        let len = file.metadata().with_context(|| format!("stat {path:?}"))?.len();
        if len < self.offset {
            *self = StreamTail::default();
            *state = TopState::default();
        }
        file.seek(SeekFrom::Start(self.offset)).context("seeking stream")?;
        let mut chunk = [0u8; 64 * 1024];
        let mut folded = 0;
        loop {
            let n = file.read(&mut chunk).context("reading stream")?;
            if n == 0 {
                break;
            }
            self.offset += n as u64;
            self.reader.feed(&chunk[..n]);
            while let Some(value) = self.reader.next_value() {
                let raw = match value {
                    Ok(raw) => raw,
                    Err(e) => {
                        state.note_damage(self.reader.line(), &e.msg);
                        continue;
                    }
                };
                match RunEvent::from_json(&raw) {
                    Ok(ev) => {
                        state.fold(&ev, &raw);
                        folded += 1;
                    }
                    Err(e) => state.note_damage(self.reader.line(), &format!("{e:#}")),
                }
            }
        }
        Ok(folded)
    }
}

/// One-shot `top`: fold the whole stream as it stands and return the
/// rendered screen (the CLI's non-follow mode; also what tests drive).
pub fn top_once(path: &Path) -> Result<String> {
    let mut state = TopState::default();
    let mut tail = StreamTail::default();
    tail.poll(path, &mut state)?;
    Ok(state.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_stream(name: &str, body: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("ecsgmcmc-top-test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        std::fs::write(&p, body).unwrap();
        p
    }

    const STREAM: &str = concat!(
        "{\"ev\":\"meta\",\"version\":3,\"scheme\":\"ec_sghmc\",\"workers\":2,\"seed\":\"9\"}\n",
        "{\"ev\":\"u\",\"chain\":0,\"step\":99,\"t\":0.5,\"u\":2.5}\n",
        "{\"ev\":\"sample\",\"chain\":0,\"t\":0.6,\"theta\":[1.5,-0.25]}\n",
        "{\"ev\":\"sample\",\"chain\":1,\"t\":0.55,\"theta\":[0.5,0.75]}\n",
        "{\"ev\":\"telemetry\",\"t\":0.7,\"center_steps\":50,\"spans_dropped\":0,",
        "\"stages\":{\"exchange\":{\"count\":25,\"total_ns\":50000,\"p50_ns\":1500,",
        "\"p95_ns\":4000,\"p99_ns\":9000,\"max_ns\":9500}},",
        "\"staleness\":{\"count\":25,\"mean\":0.4,\"p50\":0,\"p95\":2,\"p99\":3,\"max\":3},",
        "\"queue_depth\":{\"count\":25,\"p50\":1,\"p95\":2,\"p99\":2,\"max\":2}}\n",
    );

    #[test]
    fn top_renders_rates_stages_and_staleness() {
        let p = write_stream("a.jsonl", STREAM);
        let screen = top_once(&p).unwrap();
        assert!(screen.contains("scheme ec_sghmc"), "{screen}");
        assert!(screen.contains("exchange"), "{screen}");
        assert!(screen.contains("staleness: mean 0.40  p50 0  p95 2  p99 3  max 3"), "{screen}");
        assert!(screen.contains("queue depth: p50 1"), "{screen}");
        // chain 0 rate: 100 steps / 0.6s ≈ 166.7
        assert!(screen.contains("166.7"), "{screen}");
        assert!(screen.contains("diag: n=2 chains=2"), "{screen}");
    }

    #[test]
    fn tail_folds_only_appended_bytes() {
        let meta =
            "{\"ev\":\"meta\",\"version\":3,\"scheme\":\"ec\",\"workers\":1,\"seed\":\"1\"}\n";
        let p = write_stream("b.jsonl", meta);
        let mut state = TopState::default();
        let mut tail = StreamTail::default();
        assert_eq!(tail.poll(&p, &mut state).unwrap(), 1);
        assert_eq!(tail.poll(&p, &mut state).unwrap(), 0);
        let mut f = std::fs::OpenOptions::new().append(true).open(&p).unwrap();
        use std::io::Write;
        writeln!(f, "{{\"ev\":\"u\",\"chain\":0,\"step\":9,\"t\":0.1,\"u\":1.0}}").unwrap();
        drop(f);
        assert_eq!(tail.poll(&p, &mut state).unwrap(), 1);
        assert!(state.render().contains("10"), "{}", state.render());
    }

    #[test]
    fn health_events_render_in_the_header() {
        let body = concat!(
            "{\"ev\":\"meta\",\"version\":4,\"scheme\":\"ec\",\"workers\":2,\"seed\":\"1\"}\n",
            "{\"ev\":\"health\",\"t\":0.2,\"center_steps\":40,\"status\":\"degraded\",",
            "\"workers_active\":1,\"stalled_chains\":[1],\"divergent\":false,",
            "\"theta_norm\":2.5,\"reject_rate\":0,\"ess_per_sec\":null,",
            "\"ess_trend\":0,\"reasons\":[\"chain 1 stalled\"]}\n",
        );
        let p = write_stream("health.jsonl", body);
        let screen = top_once(&p).unwrap();
        assert!(
            screen.contains("health: degraded — 1 active, 1 stalled — chain 1 stalled"),
            "{screen}"
        );
    }

    #[test]
    fn follow_survives_torn_and_corrupt_lines_mid_stream() {
        let meta =
            "{\"ev\":\"meta\",\"version\":3,\"scheme\":\"ec\",\"workers\":1,\"seed\":\"1\"}\n";
        let p = write_stream("torn.jsonl", meta);
        let mut state = TopState::default();
        let mut tail = StreamTail::default();
        assert_eq!(tail.poll(&p, &mut state).unwrap(), 1);
        use std::io::Write;
        // A torn (incomplete) line: not damage yet, just buffered bytes.
        let mut f = std::fs::OpenOptions::new().append(true).open(&p).unwrap();
        f.write_all(b"{\"ev\":\"sample\",\"chain\":0,\"t\":0.1,\"the").unwrap();
        drop(f);
        assert_eq!(tail.poll(&p, &mut state).unwrap(), 0);
        assert_eq!(state.damaged, 0, "incomplete tail is not damage");
        // The writer finishes the line: it folds on the next poll.
        let mut f = std::fs::OpenOptions::new().append(true).open(&p).unwrap();
        f.write_all(b"ta\":[1.5]}\n").unwrap();
        // Then genuinely corrupt bytes, then a valid event after them.
        f.write_all(b"{corrupt garbage\n").unwrap();
        f.write_all(b"{\"ev\":\"vibes\"}\n").unwrap();
        f.write_all(b"{\"ev\":\"u\",\"chain\":0,\"step\":9,\"t\":0.2,\"u\":1.0}\n").unwrap();
        drop(f);
        assert_eq!(tail.poll(&p, &mut state).unwrap(), 2, "sample + u fold, damage skipped");
        assert_eq!(state.damaged, 2, "bad json + unknown event both counted");
        let screen = state.render();
        assert!(screen.contains("stream damage: 2 undecodable line(s)"), "{screen}");
        assert!(screen.contains("line 3"), "first damage names its line: {screen}");
    }

    #[test]
    fn shrunken_stream_restarts_the_fold() {
        let meta =
            "{\"ev\":\"meta\",\"version\":3,\"scheme\":\"ec\",\"workers\":1,\"seed\":\"1\"}\n";
        let two = format!("{meta}{{\"ev\":\"u\",\"chain\":0,\"step\":9,\"t\":0.1,\"u\":1.0}}\n");
        let p = write_stream("shrink.jsonl", &two);
        let mut state = TopState::default();
        let mut tail = StreamTail::default();
        assert_eq!(tail.poll(&p, &mut state).unwrap(), 2);
        // A resume truncates the stream below our offset.
        std::fs::write(&p, meta).unwrap();
        assert_eq!(tail.poll(&p, &mut state).unwrap(), 1, "re-folds from scratch");
        assert_eq!(state.events, 1);
    }

    #[test]
    fn stream_without_telemetry_says_so() {
        let p = write_stream(
            "c.jsonl",
            "{\"ev\":\"meta\",\"version\":3,\"scheme\":\"ec\",\"workers\":1,\"seed\":\"1\"}\n",
        );
        let screen = top_once(&p).unwrap();
        assert!(screen.contains("no telemetry events yet"), "{screen}");
    }
}
