//! Property-testing micro-framework (proptest is not available offline).
//!
//! Usage:
//!
//! ```no_run
//! // (no_run: doctest executables miss the libxla_extension rpath on
//! // this image; the module's unit tests exercise the same API.)
//! use ecsgmcmc::testing::{Prop, gens};
//!
//! Prop::new("abs is non-negative")
//!     .cases(200)
//!     .run(|rng| {
//!         let x = gens::f64_range(rng, -1e6, 1e6);
//!         assert!(x.abs() >= 0.0);
//!     });
//! ```
//!
//! Each case draws from a seeded [`Pcg64`](crate::math::rng::Pcg64); on
//! failure the panic message reports the case seed so the exact input can
//! be replayed with `.replay(seed)`. Set `ECSGMCMC_PROP_CASES` to scale the
//! case count globally (CI can crank it up).

use crate::math::rng::Pcg64;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// A named property with a case budget.
pub struct Prop {
    name: String,
    cases: usize,
    base_seed: u64,
}

impl Prop {
    pub fn new(name: &str) -> Prop {
        // Derive a stable per-property base seed from the name so distinct
        // properties explore distinct streams.
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in name.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        Prop { name: name.to_string(), cases: 100, base_seed: h }
    }

    /// Set the number of cases (default 100, scaled by ECSGMCMC_PROP_CASES).
    pub fn cases(mut self, n: usize) -> Prop {
        self.cases = n;
        self
    }

    fn effective_cases(&self) -> usize {
        match std::env::var("ECSGMCMC_PROP_CASES").ok().and_then(|v| v.parse::<usize>().ok()) {
            Some(n) => n,
            None => self.cases,
        }
    }

    /// Run the property; panics with the failing case seed on error.
    pub fn run<F: FnMut(&mut Pcg64)>(&self, mut body: F) {
        for case in 0..self.effective_cases() {
            let seed = self.base_seed.wrapping_add(case as u64);
            let mut rng = Pcg64::seeded(seed);
            let result = catch_unwind(AssertUnwindSafe(|| body(&mut rng)));
            if let Err(payload) = result {
                let msg = payload
                    .downcast_ref::<String>()
                    .map(|s| s.as_str())
                    .or_else(|| payload.downcast_ref::<&str>().copied())
                    .unwrap_or("<non-string panic>");
                panic!(
                    "property '{}' failed on case {case} (replay seed {seed}): {msg}",
                    self.name
                );
            }
        }
    }

    /// Re-run a single failing case by seed (for debugging).
    pub fn replay<F: FnMut(&mut Pcg64)>(&self, seed: u64, mut body: F) {
        let mut rng = Pcg64::seeded(seed);
        body(&mut rng);
    }
}

/// Common generators.
pub mod gens {
    use crate::math::rng::Pcg64;

    pub fn usize_range(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn f64_range(rng: &mut Pcg64, lo: f64, hi: f64) -> f64 {
        lo + rng.next_f64() * (hi - lo)
    }

    /// Log-uniform positive value in [lo, hi].
    pub fn f64_log_range(rng: &mut Pcg64, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi >= lo);
        (f64_range(rng, lo.ln(), hi.ln())).exp()
    }

    /// Vector of standard normals.
    pub fn normal_vec(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        let mut v = vec![0f32; n];
        rng.fill_normal(&mut v);
        v
    }

    /// Vector of uniforms in [lo, hi).
    pub fn uniform_vec(rng: &mut Pcg64, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| lo + rng.next_f32() * (hi - lo)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        Prop::new("trivially true").cases(25).run(|_| {
            count += 1;
        });
        assert_eq!(count, 25);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            Prop::new("always fails").cases(3).run(|_| panic!("boom"));
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("replay seed"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn cases_are_deterministic_across_runs() {
        let mut first = Vec::new();
        Prop::new("collect").cases(5).run(|rng| first.push(rng.next_u64()));
        let mut second = Vec::new();
        Prop::new("collect").cases(5).run(|rng| second.push(rng.next_u64()));
        assert_eq!(first, second);
    }

    #[test]
    fn generators_respect_bounds() {
        Prop::new("gen bounds").cases(50).run(|rng| {
            let u = gens::usize_range(rng, 3, 9);
            assert!((3..=9).contains(&u));
            let f = gens::f64_range(rng, -2.0, 5.0);
            assert!((-2.0..5.0).contains(&f));
            let lg = gens::f64_log_range(rng, 1e-6, 1e3);
            assert!((1e-6..=1e3).contains(&lg));
            let v = gens::uniform_vec(rng, 4, 0.0, 1.0);
            assert!(v.iter().all(|&x| (0.0..1.0).contains(&x)));
        });
    }
}
