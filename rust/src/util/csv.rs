//! Tiny CSV writer for experiment traces (figure data series).
//!
//! Output is consumed by plotting scripts / spreadsheets; fields containing
//! commas/quotes/newlines are quoted per RFC 4180.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Buffered CSV writer with a fixed header.
pub struct CsvWriter<W: Write> {
    out: W,
    columns: usize,
}

impl CsvWriter<BufWriter<File>> {
    /// Create a CSV file and write the header row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file = BufWriter::new(File::create(path)?);
        Self::new(file, header)
    }
}

impl<W: Write> CsvWriter<W> {
    pub fn new(mut out: W, header: &[&str]) -> std::io::Result<Self> {
        write_row_raw(&mut out, header)?;
        Ok(Self { out, columns: header.len() })
    }

    /// Write one row of string fields; panics if the arity differs from the
    /// header (programming error, not runtime input).
    pub fn row(&mut self, fields: &[&str]) -> std::io::Result<()> {
        assert_eq!(fields.len(), self.columns, "csv row arity mismatch");
        write_row_raw(&mut self.out, fields)
    }

    /// Convenience: write a row of f64 values with full precision.
    pub fn row_f64(&mut self, fields: &[f64]) -> std::io::Result<()> {
        let strs: Vec<String> = fields.iter().map(|v| format!("{v}")).collect();
        let refs: Vec<&str> = strs.iter().map(|s| s.as_str()).collect();
        self.row(&refs)
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

fn write_row_raw<W: Write>(out: &mut W, fields: &[&str]) -> std::io::Result<()> {
    for (i, f) in fields.iter().enumerate() {
        if i > 0 {
            out.write_all(b",")?;
        }
        if f.contains([',', '"', '\n']) {
            let escaped = f.replace('"', "\"\"");
            write!(out, "\"{escaped}\"")?;
        } else {
            out.write_all(f.as_bytes())?;
        }
    }
    out.write_all(b"\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let mut buf = Vec::new();
        let mut w = CsvWriter::new(&mut buf, &["a", "b"]).unwrap();
        w.row(&["1", "x,y"]).unwrap();
        w.row_f64(&[0.5, 2.0]).unwrap();
        drop(w);
        let text = String::from_utf8(buf).unwrap();
        assert_eq!(text, "a,b\n1,\"x,y\"\n0.5,2\n");
    }

    #[test]
    fn escapes_quotes() {
        let mut buf = Vec::new();
        let mut w = CsvWriter::new(&mut buf, &["v"]).unwrap();
        w.row(&["he said \"hi\""]).unwrap();
        drop(w);
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn panics_on_arity_mismatch() {
        let mut buf = Vec::new();
        let mut w = CsvWriter::new(&mut buf, &["a", "b"]).unwrap();
        let _ = w.row(&["only-one"]);
    }
}
