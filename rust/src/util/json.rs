//! Minimal JSON parser + emitter.
//!
//! Built from scratch because no serde facade is available offline. Scope:
//! the full JSON grammar minus `\u` surrogate pairs (accepted, mapped to
//! the replacement char when invalid). Used for the artifact manifest
//! (`artifacts/manifest.json`), bench reports, and experiment result dumps.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so emission is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns `None` on any miss.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_of_f64(values: &[f64]) -> Json {
        Json::Arr(values.iter().map(|v| Json::Num(*v)).collect())
    }

    /// Parse a JSON document. Errors carry a byte offset for debugging.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Emit compact JSON.
    pub fn emit(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Emit pretty-printed JSON with 2-space indentation.
    pub fn emit_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        out.push_str(&format!("{}", *n as i64));
                    } else {
                        out.push_str(&format!("{n}"));
                    }
                } else {
                    // JSON has no Inf/NaN; emit null like most serializers.
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap()[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parse_empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"n":null,"nested":{"t":true}}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.emit();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
    }

    #[test]
    fn roundtrip_escapes() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(Json::parse(&v.emit()).unwrap(), v);
    }

    #[test]
    fn pretty_print_parses_back() {
        let v = Json::from_pairs(vec![
            ("x", Json::arr_of_f64(&[1.0, 2.0])),
            ("y", Json::Str("z".into())),
        ]);
        assert_eq!(Json::parse(&v.emit_pretty()).unwrap(), v);
    }

    #[test]
    fn integers_emit_without_decimal_point() {
        assert_eq!(Json::Num(5.0).emit(), "5");
        assert_eq!(Json::Num(5.25).emit(), "5.25");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"version":1,"artifacts":{"g":{"file":"g.hlo.txt",
            "inputs":[{"name":"theta","shape":[2],"dtype":"f32"}],
            "outputs":[{"name":"u","shape":[],"dtype":"f32"}],
            "meta":{"padded_n":2}}}}"#;
        let v = Json::parse(src).unwrap();
        let inp = v.path(&["artifacts", "g", "inputs"]).unwrap().as_arr().unwrap();
        assert_eq!(inp[0].get("shape").unwrap().as_arr().unwrap()[0].as_usize(), Some(2));
    }
}
