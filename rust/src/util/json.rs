//! Minimal JSON parser + emitter, plus the streaming layer the sink
//! subsystem is built on (DESIGN.md §7).
//!
//! Built from scratch because no serde facade is available offline. Scope:
//! the full JSON grammar minus `\u` surrogate pairs (accepted, mapped to
//! the replacement char when invalid). Used for the artifact manifest
//! (`artifacts/manifest.json`), bench reports, and experiment result dumps.
//!
//! Two entry points exist per direction:
//!
//! * tree — [`Json::parse`] / [`Json::emit`]: whole document in memory;
//! * streaming — [`Emitter`] (token-at-a-time writer, no intermediate
//!   tree) and [`StreamReader`] (feed bytes in arbitrary chunks, pull
//!   complete line-framed values). Both keep memory bounded by the
//!   largest single record, never by the stream length, and share the
//!   number formatting of the tree emitter so values round-trip
//!   identically through either path.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so emission is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns `None` on any miss.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_of_f64(values: &[f64]) -> Json {
        Json::Arr(values.iter().map(|v| Json::Num(*v)).collect())
    }

    /// Parse a JSON document. Errors carry a byte offset for debugging.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Emit compact JSON.
    pub fn emit(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Emit pretty-printed JSON with 2-space indentation.
    pub fn emit_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => fmt_f64(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Shared f64 formatting: integers without a decimal point, non-finite as
/// `null` (JSON has no NaN/Inf). Both the tree emitter and [`Emitter`] go
/// through here so the two paths byte-agree.
fn fmt_f64(out: &mut String, n: f64) {
    use std::fmt::Write as _;
    if n.is_finite() {
        if n == n.trunc() && n.abs() < 1e15 {
            let _ = write!(out, "{}", n as i64);
        } else {
            let _ = write!(out, "{n}");
        }
    } else {
        out.push_str("null");
    }
}

/// f32 formatting via the *f32* `Display` impl: Rust prints the shortest
/// decimal that parses back to the same f32, so a reader that parses the
/// text as f64 and narrows recovers the original bits — θ samples survive
/// the JSONL round trip exactly.
fn fmt_f32(out: &mut String, n: f32) {
    use std::fmt::Write as _;
    if n.is_finite() {
        if n == n.trunc() && n.abs() < 1e15 {
            let _ = write!(out, "{}", n as i64);
        } else {
            let _ = write!(out, "{n}");
        }
    } else {
        out.push_str("null");
    }
}

/// Incremental JSON emitter: tokens are appended straight to an internal
/// `String` with automatic comma/colon placement — no [`Json`] tree is
/// built, so emitting a record costs one reusable buffer of the record's
/// own size. The sink layer formats one JSONL event per [`clear`]d buffer.
///
/// Misuse (a value where only a key is legal, unbalanced `end_*`) is a
/// logic error; the emitter keeps best-effort state rather than
/// validating the full grammar — callers are the crate's own fixed event
/// shapes, checked by the round-trip tests.
///
/// [`clear`]: Emitter::clear
#[derive(Debug, Default)]
pub struct Emitter {
    out: String,
    /// Per nesting level: has a value already been emitted here?
    stack: Vec<bool>,
    /// The next value completes a `key:`; suppress its comma.
    after_key: bool,
}

impl Emitter {
    pub fn new() -> Emitter {
        Emitter::default()
    }

    /// Reset for the next record, keeping the allocation.
    pub fn clear(&mut self) {
        self.out.clear();
        self.stack.clear();
        self.after_key = false;
    }

    pub fn as_str(&self) -> &str {
        &self.out
    }

    pub fn into_string(self) -> String {
        self.out
    }

    fn pre_value(&mut self) {
        if self.after_key {
            self.after_key = false;
            return;
        }
        if let Some(seen) = self.stack.last_mut() {
            if *seen {
                self.out.push(',');
            }
            *seen = true;
        }
    }

    pub fn begin_obj(&mut self) -> &mut Self {
        self.pre_value();
        self.out.push('{');
        self.stack.push(false);
        self
    }

    pub fn end_obj(&mut self) -> &mut Self {
        let popped = self.stack.pop();
        debug_assert!(popped.is_some(), "end_obj with no open container");
        self.out.push('}');
        self
    }

    pub fn begin_arr(&mut self) -> &mut Self {
        self.pre_value();
        self.out.push('[');
        self.stack.push(false);
        self
    }

    pub fn end_arr(&mut self) -> &mut Self {
        let popped = self.stack.pop();
        debug_assert!(popped.is_some(), "end_arr with no open container");
        self.out.push(']');
        self
    }

    /// Object key; the next emitted value attaches to it.
    pub fn key(&mut self, k: &str) -> &mut Self {
        if let Some(seen) = self.stack.last_mut() {
            if *seen {
                self.out.push(',');
            }
            *seen = true;
        }
        write_escaped(&mut self.out, k);
        self.out.push(':');
        self.after_key = true;
        self
    }

    pub fn num(&mut self, n: f64) -> &mut Self {
        self.pre_value();
        fmt_f64(&mut self.out, n);
        self
    }

    pub fn num_f32(&mut self, n: f32) -> &mut Self {
        self.pre_value();
        fmt_f32(&mut self.out, n);
        self
    }

    pub fn str_val(&mut self, s: &str) -> &mut Self {
        self.pre_value();
        write_escaped(&mut self.out, s);
        self
    }

    pub fn bool_val(&mut self, b: bool) -> &mut Self {
        self.pre_value();
        self.out.push_str(if b { "true" } else { "false" });
        self
    }

    pub fn null(&mut self) -> &mut Self {
        self.pre_value();
        self.out.push_str("null");
        self
    }

    /// Whole f32 array in one call — the θ-sample hot path.
    pub fn f32_arr(&mut self, xs: &[f32]) -> &mut Self {
        self.begin_arr();
        for &x in xs {
            self.num_f32(x);
        }
        self.end_arr()
    }
}

/// Pull-based streaming reader for line-framed JSON (JSONL): feed bytes
/// in whatever chunks arrive, pull complete top-level values as newlines
/// complete them. Only the current (possibly incomplete) line is ever
/// buffered, so memory is bounded by the largest single record no matter
/// how long the stream runs. Values split across arbitrary chunk
/// boundaries parse once their closing newline arrives; blank lines are
/// skipped; a final unterminated line is recovered by [`finish`].
///
/// A line whose newline has not arrived by the time [`DEFAULT_MAX_LINE`]
/// bytes are buffered is abandoned: the reader reports one error naming
/// the line, drops what it buffered, and discards until the next newline
/// — so a corrupt or adversarial stream (a missing newline splicing two
/// records, a multi-gigabyte "line") cannot grow memory without bound.
///
/// [`finish`]: StreamReader::finish
#[derive(Debug)]
pub struct StreamReader {
    buf: Vec<u8>,
    /// Consumed prefix of `buf`; compacted once per [`feed`], not per
    /// line, so pulling n lines from a chunk is O(chunk), not O(n·chunk).
    ///
    /// [`feed`]: StreamReader::feed
    pos: usize,
    /// Lines consumed so far (1-based in error messages).
    line: usize,
    /// Buffered-bytes cap for a single unterminated line.
    max_line: usize,
    /// An overlong line was abandoned; discard until the next newline.
    skipping: bool,
}

/// Default single-line cap (64 MiB): far above any record the sink emits,
/// far below what would threaten the process.
pub const DEFAULT_MAX_LINE: usize = 64 << 20;

impl Default for StreamReader {
    fn default() -> StreamReader {
        StreamReader::new()
    }
}

impl StreamReader {
    pub fn new() -> StreamReader {
        StreamReader::with_max_line(DEFAULT_MAX_LINE)
    }

    /// Reader with a custom single-line byte cap (tests use small caps).
    pub fn with_max_line(max_line: usize) -> StreamReader {
        StreamReader { buf: Vec::new(), pos: 0, line: 0, max_line, skipping: false }
    }

    pub fn feed(&mut self, bytes: &[u8]) {
        if self.pos > 0 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes held for the incomplete tail line (the memory bound).
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Lines consumed so far (the 1-based number of the last line pulled).
    pub fn line(&self) -> usize {
        self.line
    }

    /// Next complete value, if a full line has been fed.
    pub fn next_value(&mut self) -> Option<Result<Json, JsonError>> {
        loop {
            if self.skipping {
                // Discard the remainder of an abandoned overlong line
                // (already reported and counted) without buffering it.
                match self.buf[self.pos..].iter().position(|&b| b == b'\n') {
                    Some(rel) => {
                        self.pos += rel + 1;
                        self.skipping = false;
                    }
                    None => {
                        self.pos = self.buf.len();
                        return None;
                    }
                }
            }
            let rel = match self.buf[self.pos..].iter().position(|&b| b == b'\n') {
                Some(rel) => rel,
                None => {
                    if self.buffered() > self.max_line {
                        self.line += 1;
                        self.pos = self.buf.len();
                        self.skipping = true;
                        return Some(Err(JsonError {
                            msg: format!(
                                "line {}: line exceeds {} bytes without a newline; skipping",
                                self.line, self.max_line
                            ),
                            offset: 0,
                        }));
                    }
                    return None;
                }
            };
            let nl = self.pos + rel;
            self.line += 1;
            let parsed = {
                let text = trim_ascii_ws(&self.buf[self.pos..nl]);
                if text.is_empty() {
                    None
                } else {
                    Some(parse_line(text, self.line))
                }
            };
            self.pos = nl + 1;
            if let Some(result) = parsed {
                return Some(result);
            }
        }
    }

    /// End-of-stream flush: parse a final line missing its newline.
    pub fn finish(&mut self) -> Option<Result<Json, JsonError>> {
        let buf = std::mem::take(&mut self.buf);
        let pos = std::mem::take(&mut self.pos);
        if self.skipping {
            // The tail is the remainder of an already-reported overlong
            // line; there is nothing recoverable in it.
            self.skipping = false;
            return None;
        }
        let text = trim_ascii_ws(&buf[pos..]);
        if text.is_empty() {
            return None;
        }
        self.line += 1;
        Some(parse_line(text, self.line))
    }
}

// Equivalent to `<[u8]>::trim_ascii` (std, stable since 1.80); kept
// hand-rolled because this crate avoids assuming a recent MSRV beyond
// what the rest of the code already requires.
fn trim_ascii_ws(mut bytes: &[u8]) -> &[u8] {
    while let [first, rest @ ..] = bytes {
        if first.is_ascii_whitespace() {
            bytes = rest;
        } else {
            break;
        }
    }
    while let [rest @ .., last] = bytes {
        if last.is_ascii_whitespace() {
            bytes = rest;
        } else {
            break;
        }
    }
    bytes
}

fn parse_line(text: &[u8], line: usize) -> Result<Json, JsonError> {
    let s = std::str::from_utf8(text)
        .map_err(|_| JsonError { msg: format!("line {line}: invalid utf-8"), offset: 0 })?;
    Json::parse(s)
        .map_err(|e| JsonError { msg: format!("line {line}: {}", e.msg), offset: e.offset })
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Containers deeper than this are rejected instead of recursing further:
/// `value → array → value → …` descends one stack frame per level, so an
/// adversarial `[[[[…` would otherwise overflow the stack long before it
/// exhausts memory. 128 is far beyond any document this crate emits.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Current container nesting, bounded by [`MAX_DEPTH`].
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        Ok(())
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        self.enter()?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        self.enter()?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap()[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parse_empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"n":null,"nested":{"t":true}}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.emit();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
    }

    #[test]
    fn roundtrip_escapes() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(Json::parse(&v.emit()).unwrap(), v);
    }

    #[test]
    fn pretty_print_parses_back() {
        let v = Json::from_pairs(vec![
            ("x", Json::arr_of_f64(&[1.0, 2.0])),
            ("y", Json::Str("z".into())),
        ]);
        assert_eq!(Json::parse(&v.emit_pretty()).unwrap(), v);
    }

    #[test]
    fn integers_emit_without_decimal_point() {
        assert_eq!(Json::Num(5.0).emit(), "5");
        assert_eq!(Json::Num(5.25).emit(), "5.25");
    }

    /// Deterministic pseudo-random JSON tree for the round-trip property.
    fn random_json(rng: &mut crate::math::rng::Pcg64, depth: usize) -> Json {
        let pick = rng.next_u64() % if depth == 0 { 4 } else { 6 };
        match pick {
            0 => Json::Null,
            1 => Json::Bool(rng.next_u64() % 2 == 0),
            2 => {
                // Mix integral and fractional magnitudes.
                let raw = rng.next_normal() * 10f64.powi((rng.next_u64() % 7) as i32 - 3);
                Json::Num(if rng.next_u64() % 3 == 0 { raw.trunc() } else { raw })
            }
            3 => {
                let n = rng.next_u64() % 8;
                Json::Str(
                    (0..n)
                        .map(|_| {
                            ['a', 'β', '"', '\\', '\n', '\t', ' ', 'z']
                                [(rng.next_u64() % 8) as usize]
                        })
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.next_u64() % 4).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.next_u64() % 4)
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }

    #[test]
    fn roundtrip_property_emit_parse_emit_identical() {
        let mut rng = crate::math::rng::Pcg64::seeded(1612);
        for _ in 0..200 {
            let v = random_json(&mut rng, 3);
            let emitted = v.emit();
            let parsed = Json::parse(&emitted).unwrap_or_else(|e| panic!("{e}: {emitted}"));
            assert_eq!(parsed, v, "parse round trip: {emitted}");
            assert_eq!(parsed.emit(), emitted, "emit round trip");
        }
    }

    #[test]
    fn parser_rejects_bare_nan_and_inf() {
        for bad in ["NaN", "nan", "Infinity", "-Infinity", "inf", "-inf", "[1,NaN]"] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn parser_rejects_truncation_and_trailing_garbage() {
        for bad in [
            "{\"a\":",
            "{\"a\":1",
            "[1,2",
            "\"open",
            "{\"a\":1} x",
            "[1] [2]",
            "123abc",
            "tru",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn nonfinite_numbers_emit_as_null() {
        assert_eq!(Json::Num(f64::NAN).emit(), "null");
        assert_eq!(Json::Num(f64::INFINITY).emit(), "null");
        let mut e = Emitter::new();
        e.begin_arr().num_f32(f32::NAN).num(f64::NEG_INFINITY).end_arr();
        assert_eq!(e.as_str(), "[null,null]");
    }

    #[test]
    fn emitter_matches_tree_emitter() {
        // Same document, keys in BTreeMap (alphabetical) order.
        let tree = Json::from_pairs(vec![
            ("arr", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5), Json::Str("s\n".into())])),
            ("b", Json::Bool(true)),
            ("n", Json::Null),
            ("obj", Json::from_pairs(vec![("x", Json::Num(-3.0))])),
        ]);
        let mut e = Emitter::new();
        e.begin_obj();
        e.key("arr").begin_arr().num(1.0).num(2.5).str_val("s\n").end_arr();
        e.key("b").bool_val(true);
        e.key("n").null();
        e.key("obj").begin_obj();
        e.key("x").num(-3.0);
        e.end_obj();
        e.end_obj();
        assert_eq!(e.as_str(), tree.emit());
    }

    #[test]
    fn emitter_comma_after_nested_container() {
        // A container in non-final position must be followed by a comma
        // (regression: the level pop must happen in release builds too).
        let mut e = Emitter::new();
        e.begin_obj();
        e.key("a").begin_obj();
        e.end_obj();
        e.key("b").num(1.0);
        e.key("c").begin_arr().num(2.0).end_arr();
        e.key("d").bool_val(false);
        e.end_obj();
        assert_eq!(e.as_str(), "{\"a\":{},\"b\":1,\"c\":[2],\"d\":false}");
        assert!(Json::parse(e.as_str()).is_ok());
    }

    #[test]
    fn emitter_clear_reuses_buffer() {
        let mut e = Emitter::new();
        e.begin_obj();
        e.key("a").num(1.0);
        e.end_obj();
        assert_eq!(e.as_str(), "{\"a\":1}");
        e.clear();
        e.begin_arr().num(2.0).end_arr();
        assert_eq!(e.as_str(), "[2]");
    }

    #[test]
    fn f32_values_roundtrip_exactly_through_text() {
        let mut rng = crate::math::rng::Pcg64::seeded(99);
        let mut values: Vec<f32> = vec![
            0.0,
            -0.0,
            0.1,
            -1.5e-8,
            1e-45,           // smallest subnormal
            f32::MIN_POSITIVE,
            f32::MAX,
            16_777_216.0,    // 2^24, the integer-precision edge
            core::f32::consts::PI,
        ];
        for _ in 0..500 {
            let x = f32::from_bits(rng.next_u64() as u32);
            if x.is_finite() {
                values.push(x);
            }
        }
        let mut e = Emitter::new();
        e.f32_arr(&values);
        let parsed = Json::parse(e.as_str()).unwrap();
        let back: Vec<f32> = parsed
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        for (a, b) in values.iter().zip(&back) {
            assert_eq!(a, b, "f32 {a:?} -> {b:?}");
        }
    }

    #[test]
    fn stream_reader_resumes_across_arbitrary_chunk_boundaries() {
        let doc = "{\"a\":1}\n\n  [1,2,3]\r\n\"x\\n\"\n{\"nested\":{\"b\":[true]}}\n";
        let expect = vec![
            Json::parse("{\"a\":1}").unwrap(),
            Json::parse("[1,2,3]").unwrap(),
            Json::parse("\"x\\n\"").unwrap(),
            Json::parse("{\"nested\":{\"b\":[true]}}").unwrap(),
        ];
        for chunk in [1usize, 2, 3, 7, 64, doc.len()] {
            let mut r = StreamReader::new();
            let mut got = Vec::new();
            for c in doc.as_bytes().chunks(chunk) {
                r.feed(c);
                while let Some(v) = r.next_value() {
                    got.push(v.unwrap());
                }
            }
            assert!(r.finish().is_none(), "chunk={chunk}: trailing data");
            assert_eq!(got, expect, "chunk={chunk}");
            assert_eq!(r.buffered(), 0);
        }
    }

    #[test]
    fn stream_reader_finish_recovers_unterminated_tail() {
        let mut r = StreamReader::new();
        r.feed(b"{\"a\":1}\n{\"b\":");
        assert_eq!(r.next_value().unwrap().unwrap(), Json::parse("{\"a\":1}").unwrap());
        assert!(r.next_value().is_none());
        r.feed(b"2}");
        assert!(r.next_value().is_none()); // still no newline
        assert_eq!(r.finish().unwrap().unwrap(), Json::parse("{\"b\":2}").unwrap());
        assert!(r.finish().is_none());
    }

    #[test]
    fn stream_reader_reports_malformed_lines_with_line_numbers() {
        let mut r = StreamReader::new();
        r.feed(b"{\"ok\":1}\nnot json\n");
        assert!(r.next_value().unwrap().is_ok());
        let err = r.next_value().unwrap().unwrap_err();
        assert!(err.msg.contains("line 2"), "{err}");
        // The reader keeps going after an error line.
        r.feed(b"[4]\n");
        assert_eq!(r.next_value().unwrap().unwrap(), Json::parse("[4]").unwrap());
    }

    #[test]
    fn parser_rejects_pathological_nesting_without_overflowing() {
        // Depth within the limit parses fine…
        let ok = format!("{}{}", "[".repeat(64), "]".repeat(64));
        assert!(Json::parse(&ok).is_ok());
        // …depth beyond it is an error, not a stack overflow. 100k open
        // brackets would blow the stack at one frame per level.
        for bad in
            ["[".repeat(100_000), format!("{}1{}", "[".repeat(129), "]".repeat(129))]
        {
            let err = Json::parse(&bad).unwrap_err();
            assert!(err.msg.contains("nesting too deep"), "{err}");
        }
        let deep_obj = format!("{}1{}", "{\"k\":".repeat(200), "}".repeat(200));
        assert!(Json::parse(&deep_obj).unwrap_err().msg.contains("nesting too deep"));
    }

    #[test]
    fn stream_reader_abandons_overlong_lines_and_recovers() {
        let mut r = StreamReader::with_max_line(64);
        r.feed(b"{\"ok\":1}\n");
        assert!(r.next_value().unwrap().is_ok());
        // An unterminated line grows past the cap: one error naming the
        // line, buffered bytes released, remainder discarded.
        r.feed(&[b'a'; 100]);
        let err = r.next_value().unwrap().unwrap_err();
        assert!(err.msg.contains("line 2"), "{err}");
        assert!(err.msg.contains("exceeds 64 bytes"), "{err}");
        assert_eq!(r.buffered(), 0);
        r.feed(&[b'a'; 300]); // still the same abandoned line
        assert!(r.next_value().is_none());
        assert_eq!(r.buffered(), 0, "skip mode must not buffer");
        // The newline ends skip mode; subsequent lines parse normally.
        r.feed(b"aaa\n[7]\n");
        assert_eq!(r.next_value().unwrap().unwrap(), Json::parse("[7]").unwrap());
        assert_eq!(r.line(), 3);
        assert!(r.finish().is_none());
    }

    #[test]
    fn stream_reader_finish_discards_abandoned_tail() {
        let mut r = StreamReader::with_max_line(16);
        r.feed(&[b'x'; 32]);
        assert!(r.next_value().unwrap().is_err());
        r.feed(&[b'x'; 8]); // tail of the abandoned line, never terminated
        assert!(r.finish().is_none());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"version":1,"artifacts":{"g":{"file":"g.hlo.txt",
            "inputs":[{"name":"theta","shape":[2],"dtype":"f32"}],
            "outputs":[{"name":"u","shape":[],"dtype":"f32"}],
            "meta":{"padded_n":2}}}}"#;
        let v = Json::parse(src).unwrap();
        let inp = v.path(&["artifacts", "g", "inputs"]).unwrap().as_arr().unwrap();
        assert_eq!(inp[0].get("shape").unwrap().as_arr().unwrap()[0].as_usize(), Some(2));
    }
}
