//! Leveled stderr logger.
//!
//! Level is picked from `ECSGMCMC_LOG` (error|warn|info|debug|trace),
//! defaulting to `info`. Thread-safe; each line carries elapsed wall-clock
//! since process start and the emitting thread's name, which makes the
//! interleaved coordinator/worker logs readable.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static START: OnceLock<Instant> = OnceLock::new();

fn decode(raw: u8) -> Level {
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

fn current_level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == u8::MAX {
        let (lvl, bad) = match std::env::var("ECSGMCMC_LOG") {
            Ok(s) => match Level::from_str(&s) {
                Some(l) => (l, None),
                None => (Level::Info, Some(s)),
            },
            Err(_) => (Level::Info, None),
        };
        // Only the thread that wins initialization warns, so a bad
        // ECSGMCMC_LOG produces exactly one line, not one per thread.
        if LEVEL
            .compare_exchange(u8::MAX, lvl as u8, Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
        {
            if let Some(s) = bad {
                // Safe to log here: LEVEL is committed, so this re-enters
                // current_level() on the fast path.
                log(
                    Level::Warn,
                    format_args!(
                        "ECSGMCMC_LOG={s:?} is not a log level \
                         (error|warn|info|debug|trace); defaulting to info"
                    ),
                );
            }
            return lvl;
        }
        return decode(LEVEL.load(Ordering::Relaxed));
    }
    decode(raw)
}

/// Override the log level programmatically (CLI `--log-level`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level <= current_level()
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let start = *START.get_or_init(Instant::now);
    let elapsed = start.elapsed();
    let thread = std::thread::current();
    let name = thread.name().unwrap_or("?");
    let mut err = std::io::stderr().lock();
    let _ = writeln!(
        err,
        "[{:>9.3}s {} {}] {}",
        elapsed.as_secs_f64(),
        level.tag(),
        name,
        args
    );
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("debug"), Some(Level::Debug));
        assert_eq!(Level::from_str("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_str("nope"), None);
    }

    #[test]
    fn level_ordering_gates_output() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
