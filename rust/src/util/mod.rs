//! General-purpose substrates built from scratch (no crates.io access on
//! this image beyond `xla`/`anyhow`): JSON, CSV, logging, timing.

pub mod csv;
pub mod json;
pub mod logging;
pub mod timer;

/// Round `n` up to the next multiple of `m` (`m > 0`).
pub fn round_up(n: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    n.div_ceil(m) * m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
        assert_eq!(round_up(1023, 1024), 1024);
        assert_eq!(round_up(1025, 1024), 2048);
    }
}
