//! Wall-clock helpers: stopwatch and human-readable duration formatting.

use std::time::{Duration, Instant};

/// Simple stopwatch for experiment phases.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Format a duration like `1.23ms`, `4.5s`, `2m03s`, `3h25m07s`.
pub fn human_duration(d: Duration) -> String {
    human_duration_secs(d.as_secs_f64())
}

/// [`human_duration`] over fractional seconds, for durations that come
/// from a stream or a quantile (ns/1e9) rather than a live `Duration`.
/// Non-finite and negative inputs render literally rather than panic —
/// they mean the stream was damaged, and the display layer must say so.
pub fn human_duration_secs(s: f64) -> String {
    if !s.is_finite() || s < 0.0 {
        return format!("{s}s");
    }
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2}s")
    } else if s < 7200.0 {
        let mins = (s / 60.0).floor() as u64;
        format!("{mins}m{:02.0}s", s - 60.0 * mins as f64)
    } else {
        // Long-running fleets: past 120 minutes, whole seconds suffice.
        let total = s.floor() as u64;
        let (h, m, sec) = (total / 3600, (total % 3600) / 60, total % 60);
        format!("{h}h{m:02}m{sec:02}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_scales() {
        assert_eq!(human_duration(Duration::from_micros(120)), "120.0us");
        assert_eq!(human_duration(Duration::from_millis(42)), "42.00ms");
        assert_eq!(human_duration(Duration::from_secs(3)), "3.00s");
        assert_eq!(human_duration(Duration::from_secs(185)), "3m05s");
    }

    #[test]
    fn hours_branch_boundaries() {
        // The minutes form covers up to (not including) 120 minutes.
        assert_eq!(human_duration(Duration::from_secs(7199)), "119m59s");
        assert_eq!(human_duration(Duration::from_secs(7200)), "2h00m00s");
        assert_eq!(human_duration(Duration::from_secs(7265)), "2h01m05s");
        assert_eq!(human_duration(Duration::from_secs(36000)), "10h00m00s");
        assert_eq!(human_duration(Duration::from_secs(90061)), "25h01m01s");
    }

    #[test]
    fn secs_form_matches_duration_form_and_tolerates_junk() {
        assert_eq!(human_duration_secs(0.000120), "120.0us");
        assert_eq!(human_duration_secs(3.0), human_duration(Duration::from_secs(3)));
        assert_eq!(human_duration_secs(f64::NAN), "NaNs");
        assert_eq!(human_duration_secs(-1.0), "-1s");
    }

    #[test]
    fn stopwatch_monotone() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        let e1 = sw.restart();
        assert!(e1.as_secs_f64() > 0.0);
        assert!(sw.elapsed_secs() < e1.as_secs_f64() + 1.0);
    }
}
