//! Offline stub for the `xla` crate (PJRT bindings).
//!
//! Compiled when the `xla-runtime` feature is off (the default). The stub
//! mirrors the exact API surface `runtime/mod.rs` uses; its client
//! constructor returns an error, so every artifact-backed path degrades
//! the same way a missing `artifacts/` directory does: `Engine::new`
//! fails, callers print their skip marker, and the native backends carry
//! the run. Enabling `xla-runtime` (plus the real dependency — see
//! Cargo.toml) swaps this module out for the real PJRT bindings.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "XLA/PJRT runtime unavailable: built without the `xla-runtime` feature \
         (native backends still work; see rust/Cargo.toml to enable)"
            .to_string(),
    ))
}

/// Element types the runtime moves across the PJRT boundary.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

pub struct Literal(());

impl Literal {
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err}").contains("xla-runtime"), "{err}");
    }

    #[test]
    fn literal_construction_is_inert() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
