//! Batch-consistency suite (DESIGN.md §9):
//!
//! * `stoch_grad_batch` at B = 1 is **bit-identical** to `stoch_grad`
//!   for every potential (the single-group dispatch rule);
//! * at B > 1 the grouped-GEMM implementations draw exactly the same
//!   minibatches (stream positions match the unbatched loop bit-exactly)
//!   and agree with it to f32 rounding;
//! * full `run_ec` / `run_independent` jobs at `chains_per_worker = 1`
//!   run the pre-batching code path, and packing chains into blocks on
//!   the Fig. 1 Gaussian (no batched override) reproduces those runs —
//!   and their posterior moments — bit-for-bit.

use ecsgmcmc::config::RunConfig;
use ecsgmcmc::coordinator::ec::run_ec;
use ecsgmcmc::coordinator::engine::{NativeEngine, StepKind, WorkerEngine};
use ecsgmcmc::coordinator::{EcConfig, IndependentCoordinator, RunOptions};
use ecsgmcmc::data::{synth_cifar, synth_mnist};
use ecsgmcmc::diagnostics::{moments, to_f64_samples};
use ecsgmcmc::math::rng::Pcg64;
use ecsgmcmc::potentials::banana::BananaPotential;
use ecsgmcmc::potentials::gaussian::GaussianPotential;
use ecsgmcmc::potentials::logreg::LogRegPotential;
use ecsgmcmc::potentials::mixture::MixturePotential;
use ecsgmcmc::potentials::nn::mlp::NativeMlp;
use ecsgmcmc::potentials::nn::resnet::NativeResNet;
use ecsgmcmc::potentials::Potential;
use ecsgmcmc::samplers::SghmcParams;
use ecsgmcmc::testing::Prop;
use std::sync::Arc;

fn tiny_logreg() -> LogRegPotential {
    let data = synth_mnist::generate_sized(120, 5, 3, 0.1, 17);
    let (train, test) = data.split(90);
    LogRegPotential::new(train, test, 15)
}

fn tiny_mlp() -> NativeMlp {
    let data = synth_mnist::generate_sized(80, 6, 4, 0.1, 11);
    let (train, test) = data.split(60);
    NativeMlp::new(train, test, 8, 2, 10)
}

fn tiny_resnet() -> NativeResNet {
    let data = synth_cifar::generate(80, 0.2, 13);
    let (train, test) = data.split(60);
    NativeResNet::new(train, test, 8, 2, 10)
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// B = 1 through the batch API must be bit-identical to `stoch_grad`:
/// same Ũ, same gradient bits, same stream position afterwards.
fn assert_batch_of_one_bitwise(p: &dyn Potential, rng: &mut Pcg64) {
    let dim = p.dim();
    let padded = p.padded_dim();
    let mut theta = vec![0.0f32; padded];
    rng.fill_normal(&mut theta[..dim]);
    for t in theta[..dim].iter_mut() {
        *t *= 0.2;
    }
    let mut r_scalar = Pcg64::new(rng.next_u64(), 1000);
    let mut r_batch = r_scalar.clone();
    let mut g_scalar = vec![0.0f32; padded];
    let u_scalar = p.stoch_grad(&theta, &mut g_scalar, &mut r_scalar);
    let mut g_batch = vec![0.0f32; padded];
    let mut us = [0.0f64];
    p.stoch_grad_batch(&[&theta], &mut g_batch, &mut [&mut r_batch], &mut us);
    assert_eq!(bits(&g_scalar), bits(&g_batch), "{} grads diverged at B=1", p.name());
    assert_eq!(u_scalar.to_bits(), us[0].to_bits(), "{} U diverged at B=1", p.name());
    assert_eq!(r_scalar.snapshot(), r_batch.snapshot(), "{} stream diverged", p.name());
}

#[test]
fn batch_of_one_is_bitwise_for_every_potential() {
    let logreg = tiny_logreg();
    let mlp = tiny_mlp();
    let resnet = tiny_resnet();
    let gaussian = GaussianPotential::fig1();
    let mixture = MixturePotential::bimodal(4.0, 1.0);
    let banana = BananaPotential::standard();
    let pots: [&dyn Potential; 6] = [&gaussian, &mixture, &banana, &logreg, &mlp, &resnet];
    Prop::new("batch of one is bitwise").cases(10).run(|rng| {
        for p in pots {
            assert_batch_of_one_bitwise(p, rng);
        }
    });
}

/// B > 1: the grouped kernels must consume identical minibatch draws
/// (bit-exact stream positions) and agree with the unbatched loop to
/// f32 rounding on every gradient coordinate and Ũ.
fn assert_batched_matches_scalar(p: &dyn Potential, bsz: usize, tol: f64, rng: &mut Pcg64) {
    let dim = p.dim();
    let padded = p.padded_dim();
    let thetas_data: Vec<Vec<f32>> = (0..bsz)
        .map(|_| {
            let mut t = vec![0.0f32; padded];
            rng.fill_normal(&mut t[..dim]);
            for v in t[..dim].iter_mut() {
                *v *= 0.2;
            }
            t
        })
        .collect();
    let seed = rng.next_u64();
    let mut rngs_scalar: Vec<Pcg64> =
        (0..bsz).map(|w| Pcg64::new(seed, 1000 + w as u64)).collect();
    let mut rngs_batch = rngs_scalar.clone();

    let mut g_ref = vec![0.0f32; bsz * padded];
    let mut u_ref = vec![0.0f64; bsz];
    for i in 0..bsz {
        u_ref[i] = p.stoch_grad(
            &thetas_data[i],
            &mut g_ref[i * padded..(i + 1) * padded],
            &mut rngs_scalar[i],
        );
    }

    let thetas: Vec<&[f32]> = thetas_data.iter().map(|t| t.as_slice()).collect();
    let mut rng_refs: Vec<&mut Pcg64> = rngs_batch.iter_mut().collect();
    let mut grads = vec![0.0f32; bsz * padded];
    let mut us = vec![0.0f64; bsz];
    p.stoch_grad_batch(&thetas, &mut grads, &mut rng_refs, &mut us);

    for (a, b) in rngs_scalar.iter().zip(&rngs_batch) {
        assert_eq!(a.snapshot(), b.snapshot(), "{}: minibatch draws diverged", p.name());
    }
    for i in 0..bsz {
        let du = (u_ref[i] - us[i]).abs();
        assert!(
            du <= tol * (1.0 + u_ref[i].abs()),
            "{}: chain {i} U {} vs {}",
            p.name(),
            u_ref[i],
            us[i]
        );
    }
    for (i, (&x, &y)) in g_ref.iter().zip(&grads).enumerate() {
        let (x, y) = (x as f64, y as f64);
        assert!(
            (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
            "{}: grad[{i}] {x} vs {y}",
            p.name()
        );
    }
}

#[test]
fn grouped_gradients_match_unbatched_to_rounding() {
    let logreg = tiny_logreg();
    let mlp = tiny_mlp();
    let resnet = tiny_resnet();
    Prop::new("grouped grads match").cases(6).run(|rng| {
        assert_batched_matches_scalar(&logreg, 3, 1e-3, rng);
        assert_batched_matches_scalar(&mlp, 4, 1e-3, rng);
        assert_batched_matches_scalar(&resnet, 3, 1e-3, rng);
    });
}

fn gaussian_engines(k: usize, params: SghmcParams) -> Vec<Box<dyn WorkerEngine>> {
    (0..k)
        .map(|_| {
            Box::new(NativeEngine::new(
                Arc::new(GaussianPotential::fig1()),
                params,
                StepKind::Sghmc,
            )) as Box<dyn WorkerEngine>
        })
        .collect()
}

/// Golden run on the shipped `fig1_gaussian.toml`: `chains_per_worker=1`
/// executes the pre-batching code path; packing the same fleet into
/// blocks of 2 must reproduce every trajectory — and hence the recorded
/// posterior moments — bit-for-bit (the Gaussian has no batched
/// override, so even the gradients are bitwise).
#[test]
fn fig1_ec_golden_moments_are_block_invariant() {
    let fig1 = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("configs/fig1_gaussian.toml");
    let file_cfg = RunConfig::from_file(&fig1).unwrap();
    let params = SghmcParams { eps: file_cfg.sampler.eps, ..Default::default() };
    let mk = |b: usize| EcConfig {
        workers: file_cfg.workers,
        alpha: file_cfg.alpha,
        sync_every: file_cfg.sync_every,
        steps: file_cfg.steps,
        opts: RunOptions {
            thin: 1,
            burn_in: file_cfg.steps / 4,
            log_every: (file_cfg.steps / 10).max(1),
            chains_per_worker: b,
            ..Default::default()
        },
        ..Default::default()
    };
    let run = |cfg: EcConfig| {
        let engines = gaussian_engines(file_cfg.workers, params);
        run_ec(&cfg, params, engines, file_cfg.seed)
    };
    let base = run(mk(1));
    let blocked = run(mk(2));
    assert_eq!(base.chains.len(), blocked.chains.len());
    for (a, c) in base.chains.iter().zip(&blocked.chains) {
        assert_eq!(a.samples.len(), c.samples.len(), "worker {}", a.worker);
        for (i, (sa, sc)) in a.samples.iter().zip(&c.samples).enumerate() {
            assert_eq!(sa.1, sc.1, "worker {} sample {i} diverged", a.worker);
        }
    }
    let m_base = moments(&to_f64_samples(base.thetas(), 2));
    let m_blocked = moments(&to_f64_samples(blocked.thetas(), 2));
    assert_eq!(m_base.mean, m_blocked.mean, "pooled means diverged");
    assert_eq!(m_base.cov, m_blocked.cov, "pooled covariances diverged");
    // Golden sanity: the Fig. 1 posterior is the analytic Gaussian.
    assert!(m_base.mean_error(&[0.0, 0.0]) < 0.25, "mean={:?}", m_base.mean);
    assert!(m_base.cov_error(&[1.0, 0.6, 0.6, 0.8]) < 0.5, "cov={:?}", m_base.cov);
}

/// Same invariance for the independent scheme on the fig1 problem, with
/// a block size that does not divide K (ragged last block).
#[test]
fn fig1_independent_golden_moments_are_block_invariant() {
    let fig1 = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("configs/fig1_gaussian.toml");
    let file_cfg = RunConfig::from_file(&fig1).unwrap();
    let params = SghmcParams { eps: file_cfg.sampler.eps, ..Default::default() };
    let mk = |b: usize| RunOptions {
        thin: 1,
        burn_in: file_cfg.steps / 4,
        log_every: (file_cfg.steps / 10).max(1),
        chains_per_worker: b,
        ..Default::default()
    };
    let base = IndependentCoordinator::new(file_cfg.steps, mk(1))
        .run(gaussian_engines(file_cfg.workers, params), file_cfg.seed);
    let blocked = IndependentCoordinator::new(file_cfg.steps, mk(3))
        .run(gaussian_engines(file_cfg.workers, params), file_cfg.seed);
    for (a, c) in base.chains.iter().zip(&blocked.chains) {
        assert_eq!(a.samples.len(), c.samples.len(), "worker {}", a.worker);
        for (i, (sa, sc)) in a.samples.iter().zip(&c.samples).enumerate() {
            assert_eq!(sa.1, sc.1, "worker {} sample {i} diverged", a.worker);
        }
    }
    let m_base = moments(&to_f64_samples(base.thetas(), 2));
    let m_blocked = moments(&to_f64_samples(blocked.thetas(), 2));
    assert_eq!(m_base.mean, m_blocked.mean);
    assert_eq!(m_base.cov, m_blocked.cov);
}

/// A blocked fleet on a potential WITH a batched override (the tiny MLP)
/// still draws per-chain minibatches from the right streams: the run
/// completes, every sample is finite, and per-chain sample counts match
/// the unblocked layout.
#[test]
fn mlp_blocked_fleet_is_structurally_identical() {
    let params = SghmcParams { eps: 1e-4, ..Default::default() };
    let pot = Arc::new(tiny_mlp());
    let engines = |k: usize| -> Vec<Box<dyn WorkerEngine>> {
        (0..k)
            .map(|_| {
                Box::new(NativeEngine::new(
                    pot.clone() as Arc<dyn Potential>,
                    params,
                    StepKind::Sghmc,
                )) as Box<dyn WorkerEngine>
            })
            .collect()
    };
    let mk = |b: usize| RunOptions {
        thin: 5,
        log_every: 50,
        chains_per_worker: b,
        ..Default::default()
    };
    let base = IndependentCoordinator::new(100, mk(1)).run(engines(6), 31);
    let blocked = IndependentCoordinator::new(100, mk(6)).run(engines(6), 31);
    assert_eq!(base.chains.len(), blocked.chains.len());
    for (a, c) in base.chains.iter().zip(&blocked.chains) {
        assert_eq!(a.worker, c.worker);
        assert_eq!(a.samples.len(), c.samples.len());
        assert!(c.samples.iter().all(|(_, t)| t.iter().all(|x| x.is_finite())));
    }
    assert_eq!(base.metrics.total_steps, blocked.metrics.total_steps);
}
