//! Integration tests for the checkpoint & elastic-membership runtime
//! (DESIGN.md §8):
//!
//! * kill-and-resume under the deterministic transport produces a run
//!   whose JSONL stream replays **bit-identically** to the uninterrupted
//!   run's (θ samples, Ũ values, center trajectory, metrics counters —
//!   wall-clock timestamps are the one legitimately nondeterministic
//!   field);
//! * snapshot files round-trip byte-identically through parse/serialize
//!   and reject truncation/garbage with clear errors (the unit-level
//!   property tests live in `src/checkpoint/`);
//! * a churn-enabled EC run with real join/leave events keeps split-R̂
//!   within 10% of the churn-free run on the Fig. 1 Gaussian — the
//!   acceptance scenario from the paper's abstract.

use ecsgmcmc::checkpoint::{CheckpointPolicy, CheckpointStore, Snapshot};
use ecsgmcmc::config::RunConfig;
use ecsgmcmc::coordinator::ec::{planned_spans, resume_ec, run_ec, EcCheckpoint};
use ecsgmcmc::coordinator::engine::{NativeEngine, StepKind, WorkerEngine};
use ecsgmcmc::coordinator::{ChurnModel, EcConfig, RunOptions, RunResult, TransportKind};
use ecsgmcmc::experiments::churn_sweep;
use ecsgmcmc::potentials::gaussian::GaussianPotential;
use ecsgmcmc::samplers::SghmcParams;
use ecsgmcmc::sink::replay::replay_file;
use ecsgmcmc::sink::SinkSpec;
use std::path::PathBuf;
use std::sync::Arc;

fn tmp(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ecsgmcmc-it-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn engines(n: usize, params: SghmcParams) -> Vec<Box<dyn WorkerEngine>> {
    (0..n)
        .map(|_| {
            Box::new(NativeEngine::new(
                Arc::new(GaussianPotential::fig1()),
                params,
                StepKind::Sghmc,
            )) as Box<dyn WorkerEngine>
        })
        .collect()
}

/// The deterministic content of a replayed run: θ streams per chain, Ũ
/// values, center θ trajectory, and the hard counters — everything
/// except wall-clock timestamps.
type RunView = (Vec<Vec<Vec<f32>>>, Vec<Vec<(usize, f64)>>, Vec<Vec<f32>>, [u64; 4]);

fn deterministic_view(r: &RunResult) -> RunView {
    (
        r.chains.iter().map(|c| c.samples.iter().map(|(_, t)| t.clone()).collect()).collect(),
        r.chains
            .iter()
            .map(|c| c.u_trace.iter().map(|p| (p.step, p.u)).collect())
            .collect(),
        r.center_trace.iter().map(|(_, c)| c.clone()).collect(),
        [
            r.metrics.total_steps,
            r.metrics.center_steps,
            r.metrics.exchanges,
            r.metrics.samples_dropped,
        ],
    )
}

#[test]
fn kill_and_resume_stream_replays_bit_identical_to_uninterrupted() {
    let dir = tmp("kill-resume");
    let stream = dir.join("run.jsonl");
    let ckpt_dir = dir.join("ckpt");
    let cfg = EcConfig {
        workers: 3,
        alpha: 1.0,
        sync_every: 2,
        steps: 240,
        transport: TransportKind::Deterministic,
        checkpoint: Some(EcCheckpoint {
            dir: ckpt_dir.clone(),
            policy: CheckpointPolicy { every_rounds: 30, every_secs: None, keep: 100 },
        }),
        opts: RunOptions {
            thin: 1,
            log_every: 20,
            sink: SinkSpec::Jsonl { path: stream.clone() },
            ..Default::default()
        },
        ..Default::default()
    };
    let params = SghmcParams { eps: 0.05, ..Default::default() };

    // Uninterrupted run: its stream is the reference artifact.
    run_ec(&cfg, params, engines(3, params), 99);
    let reference = replay_file(&stream).unwrap();
    let ref_view = deterministic_view(&reference);

    // "Kill": pick an interior snapshot, then corrupt the stream tail the
    // way a SIGKILL mid-write would — a complete post-cut event plus a
    // torn partial line. Resume must truncate both away and regenerate
    // the exact tail.
    let mut snaps: Vec<PathBuf> = std::fs::read_dir(&ckpt_dir)
        .unwrap()
        .flatten()
        .map(|e| e.path())
        .collect();
    snaps.sort();
    assert!(snaps.len() >= 2, "expected interior cuts: {snaps:?}");
    let snap = CheckpointStore::load(&snaps[0]).unwrap();
    assert!(snap.boundary > 0 && snap.boundary < cfg.steps);
    {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new().append(true).open(&stream).unwrap();
        f.write_all(b"{\"ev\":\"sample\",\"chain\":0,\"t\":9.9,\"theta\":[0,0]}\n").unwrap();
        f.write_all(b"{\"ev\":\"sample\",\"chain\":1,\"t\":9.95,\"the").unwrap();
    }

    let resumed = resume_ec(&cfg, params, engines(3, params), snap).unwrap();
    assert!(resumed.metrics.total_steps == reference.metrics.total_steps);
    let replayed = replay_file(&stream).unwrap();
    let got_view = deterministic_view(&replayed);
    assert_eq!(ref_view.0, got_view.0, "θ streams diverged");
    assert_eq!(ref_view.1, got_view.1, "Ũ traces diverged");
    assert_eq!(ref_view.2, got_view.2, "center trajectory diverged");
    assert_eq!(ref_view.3, got_view.3, "metrics counters diverged");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn snapshot_files_reserialize_byte_identically_and_reject_corruption() {
    let dir = tmp("snapshot-bytes");
    let cfg = EcConfig {
        workers: 2,
        sync_every: 2,
        steps: 80,
        checkpoint: Some(EcCheckpoint {
            dir: dir.join("ckpt"),
            policy: CheckpointPolicy { every_rounds: 10, every_secs: None, keep: 100 },
        }),
        opts: RunOptions { thin: 1, log_every: 10, ..Default::default() },
        ..Default::default()
    };
    let params = SghmcParams { eps: 0.04, ..Default::default() };
    run_ec(&cfg, params, engines(2, params), 7);

    let mut snaps: Vec<PathBuf> = std::fs::read_dir(dir.join("ckpt"))
        .unwrap()
        .flatten()
        .map(|e| e.path())
        .collect();
    snaps.sort();
    assert!(!snaps.is_empty());
    for path in &snaps {
        // serialize(parse(bytes)) == bytes — real files, not synthetic.
        let text = std::fs::read_to_string(path).unwrap();
        let snap = Snapshot::parse(&text).unwrap();
        assert_eq!(snap.serialize(), text, "{path:?} not byte-stable");
    }

    // Truncation: drop the footer — rejected with a clear error.
    let text = std::fs::read_to_string(&snaps[0]).unwrap();
    let cut = text.rfind("{\"ev\":\"ckpt_end\"").unwrap();
    let truncated_path = dir.join("truncated.jsonl");
    std::fs::write(&truncated_path, &text[..cut]).unwrap();
    let err = CheckpointStore::load(&truncated_path).unwrap_err();
    assert!(format!("{err:#}").contains("truncated"), "{err:#}");

    // Garbage: not JSON at all — rejected naming the line.
    let garbage_path = dir.join("garbage.jsonl");
    std::fs::write(&garbage_path, b"\x00\x01not json\n").unwrap();
    assert!(CheckpointStore::load(&garbage_path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

/// Batched-stepper churn matrix (DESIGN.md §9): a leave/join mid-run
/// with `chains_per_worker` > 1 must drain and re-seed whole chain
/// blocks without disturbing surviving chains' streams. With α = 0 the
/// elastic force vanishes, so every founder's trajectory is a pure
/// function of its own RNG streams — bit-comparable across packings
/// even on the racy lock-free fabric (only joiners, who adopt the racy
/// center θ, are excluded from the bitwise check).
#[test]
fn churned_blocks_drain_and_reseed_without_touching_survivors() {
    let churn = ChurnModel { leave_frac: 0.5, fail_frac: 0.5, join_frac: 0.5 };
    let (workers, steps, s) = (4usize, 400usize, 2usize);
    // Pick a seed whose schedule has both departures and joiners.
    let seed = (1..300)
        .find(|&sd| {
            let spans = churn.schedule(workers, steps, s, sd);
            spans.iter().any(|sp| sp.departure.is_some())
                && spans.iter().any(|sp| !sp.is_founder())
        })
        .expect("some seed churns");
    let mk = |b: usize| EcConfig {
        workers,
        alpha: 0.0,
        sync_every: s,
        steps,
        transport: TransportKind::LockFree,
        churn,
        opts: RunOptions {
            thin: 1,
            log_every: 100,
            chains_per_worker: b,
            ..Default::default()
        },
        ..Default::default()
    };
    let params = SghmcParams { eps: 0.05, ..Default::default() };
    let run = |b: usize| {
        let cfg = mk(b);
        let n = planned_spans(&cfg, seed).len();
        run_ec(&cfg, params, engines(n, params), seed)
    };
    let spans = planned_spans(&mk(1), seed);
    let base = run(1);
    // B = 3 gives ragged blocks that mix founders and joiners.
    let blocked = run(3);

    // Membership accounting is packing-invariant.
    let planned_departures = spans.iter().filter(|sp| sp.departure.is_some()).count();
    assert_eq!(base.metrics.worker_leaves as usize, planned_departures);
    assert_eq!(blocked.metrics.worker_leaves as usize, planned_departures);
    assert_eq!(base.metrics.worker_joins, blocked.metrics.worker_joins);
    assert_eq!(base.metrics.total_steps, blocked.metrics.total_steps);

    for (a, c) in base.chains.iter().zip(&blocked.chains) {
        let sp = spans[a.worker];
        assert_eq!(a.samples.len(), c.samples.len(), "worker {}", a.worker);
        if sp.is_founder() {
            // Founders (survivors AND leavers/failers) are bit-identical
            // across packings: block churn never touches their streams.
            for (i, (sa, sc)) in a.samples.iter().zip(&c.samples).enumerate() {
                assert_eq!(sa.1, sc.1, "founder {} sample {i} diverged", a.worker);
            }
        } else {
            // Joiners clone the racy center θ; counts match, contents
            // stay finite.
            assert!(c.samples.iter().all(|(_, t)| t.iter().all(|x| x.is_finite())));
        }
    }
}

/// The acceptance scenario: churn-enabled EC (join + leave + fail events
/// on the lock-free fabric, which churn requires) stays within 10% of
/// the churn-free run's split-R̂ on the `fig1_gaussian.toml` problem.
#[test]
fn churned_ec_rhat_stays_within_ten_percent_of_churn_free() {
    // The shipped Fig. 1 config supplies the problem (target, ε, K, α);
    // churn needs the lock-free fabric and enough steps for a stable R̂.
    let fig1 = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("configs/fig1_gaussian.toml");
    let file_cfg = RunConfig::from_file(&fig1).unwrap();
    let steps = 12_000;
    let mk = |churn: ChurnModel| EcConfig {
        workers: file_cfg.workers,
        alpha: file_cfg.alpha,
        sync_every: file_cfg.sync_every,
        steps,
        transport: TransportKind::LockFree,
        churn,
        opts: RunOptions {
            thin: 2,
            burn_in: steps / 5,
            log_every: steps / 10,
            ..Default::default()
        },
        ..Default::default()
    };
    let params = SghmcParams { eps: file_cfg.sampler.eps, ..Default::default() };
    let run = |cfg: EcConfig, seed: u64| {
        let n = planned_spans(&cfg, seed).len();
        run_ec(&cfg, params, engines(n, params), seed)
    };

    let free = run(mk(ChurnModel::none()), 42);
    let churn_model = ChurnModel { leave_frac: 0.5, fail_frac: 0.5, join_frac: 0.5 };
    // Pick a seed whose schedule really has joins *and* leaves.
    let seed = (42..200)
        .find(|&sd| {
            let spans = churn_model.schedule(file_cfg.workers, steps, file_cfg.sync_every, sd);
            spans.iter().any(|sp| sp.departure.is_some())
                && spans.iter().any(|sp| !sp.is_founder())
        })
        .expect("some seed churns");
    let churned = run(mk(churn_model), seed);
    assert!(churned.metrics.worker_leaves > 0, "no leave events fired");
    assert!(churned.metrics.worker_joins > 0, "no join events fired");

    let r_free = churn_sweep::max_rhat_of(&free);
    let r_churn = churn_sweep::max_rhat_of(&churned);
    assert!(r_free.is_finite() && r_churn.is_finite(), "free={r_free} churn={r_churn}");
    assert!(
        (r_churn - r_free).abs() <= 0.10 * r_free,
        "churned R-hat {r_churn:.4} deviates more than 10% from churn-free {r_free:.4}"
    );
    // Posterior moments stay sane under churn, too.
    let err = churn_sweep::cov_err(&churned);
    assert!(err < 0.5, "pooled covariance error too large under churn: {err}");
}
