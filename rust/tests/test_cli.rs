//! CLI integration tests: the binary's argument surface and config files.

use ecsgmcmc::cli::args::Parsed;
use ecsgmcmc::config::{RunConfig, Scheme};

fn argv(s: &str) -> Vec<String> {
    s.split_whitespace().map(String::from).collect()
}

#[test]
fn help_and_version_paths_exit_zero() {
    assert_eq!(ecsgmcmc::cli::run(argv("help")).unwrap(), 0);
    assert_eq!(ecsgmcmc::cli::run(argv("version")).unwrap(), 0);
    assert_eq!(ecsgmcmc::cli::run(argv("definitely-not-a-command")).unwrap(), 2);
}

#[test]
fn sample_requires_config() {
    assert!(ecsgmcmc::cli::run(argv("sample")).is_err());
}

#[test]
fn experiment_requires_id() {
    assert!(ecsgmcmc::cli::run(argv("experiment")).is_err());
    assert_eq!(ecsgmcmc::cli::run(argv("experiment --id NOPE")).unwrap(), 2);
}

#[test]
fn resume_requires_config_and_a_checkpoint_source() {
    assert!(ecsgmcmc::cli::run(argv("resume")).is_err());
    // A valid EC config but no [checkpoint] dir and no --checkpoint-dir:
    // the error names the missing knob rather than sampling from scratch.
    let dir = std::env::temp_dir().join("ecsgmcmc-test-resume-cli");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("ec.toml");
    std::fs::write(
        &cfg_path,
        "[run]\nscheme = \"ec\"\ntarget = \"gaussian\"\nsteps = 100\n[sampler]\neps = 0.05\n",
    )
    .unwrap();
    let args = vec![
        "resume".to_string(),
        "--config".to_string(),
        cfg_path.to_string_lossy().to_string(),
    ];
    let err = ecsgmcmc::cli::run(args).unwrap_err();
    assert!(format!("{err:#}").contains("checkpoint-dir"), "{err:#}");
    // Pointing at an empty checkpoint dir is also a clean error.
    let args = vec![
        "resume".to_string(),
        "--config".to_string(),
        cfg_path.to_string_lossy().to_string(),
        "--checkpoint-dir".to_string(),
        dir.join("empty-ckpts").to_string_lossy().to_string(),
    ];
    let err = ecsgmcmc::cli::run(args).unwrap_err();
    assert!(format!("{err:#}").contains("no checkpoints"), "{err:#}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fig1_experiment_runs_end_to_end() {
    let out = std::env::temp_dir().join("ecsgmcmc-test-fig1");
    let args = vec![
        "experiment".to_string(),
        "--id".to_string(),
        "FIG1".to_string(),
        "--out".to_string(),
        out.to_string_lossy().to_string(),
    ];
    assert_eq!(ecsgmcmc::cli::run(args).unwrap(), 0);
    assert!(out.join("fig1_traces.csv").exists());
    let text = std::fs::read_to_string(out.join("fig1_traces.csv")).unwrap();
    assert!(text.starts_with("scheme,chain,step,x,y"));
    assert!(text.lines().count() > 500); // 6 traces * 100 steps + header
}

#[test]
fn sample_command_with_config_file() {
    let dir = std::env::temp_dir().join("ecsgmcmc-test-cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("toy.toml");
    std::fs::write(
        &cfg_path,
        "[run]\nscheme = \"sghmc\"\ntarget = \"gaussian\"\nsteps = 200\n[sampler]\neps = 0.05\n",
    )
    .unwrap();
    let args = vec![
        "sample".to_string(),
        "--config".to_string(),
        cfg_path.to_string_lossy().to_string(),
        "--seed".to_string(),
        "9".to_string(),
    ];
    assert_eq!(ecsgmcmc::cli::run(args).unwrap(), 0);
}

#[test]
fn shipped_configs_parse_and_validate() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let mut found = 0;
    for entry in std::fs::read_dir(&dir).expect("configs/ dir exists") {
        let path = entry.unwrap().path();
        if path.extension().map(|e| e == "toml").unwrap_or(false) {
            let cfg = RunConfig::from_file(&path)
                .unwrap_or_else(|e| panic!("{path:?} invalid: {e:#}"));
            cfg.validate().unwrap();
            found += 1;
        }
    }
    assert!(found >= 4, "expected shipped configs, found {found}");
}

#[test]
fn parsed_args_accessors() {
    let p = Parsed::parse(argv("sample --config x.toml --seed 3 --fast")).unwrap();
    assert_eq!(p.command, "sample");
    assert_eq!(p.opt("config"), Some("x.toml"));
    assert!(p.has_flag("fast"));
    let _ = Scheme::from_str("ec").unwrap();
}
