//! Property tests of the coordinator invariants (DESIGN.md §5):
//!
//! * determinism: worker trajectories are a pure function of (seed, cfg);
//! * Eq. (5) decomposition: α = 0 EC workers evolve exactly like manually
//!   simulated decoupled chains with the same streams;
//! * Eq. (9) as the deterministic limit of Eq. (6);
//! * exchange accounting: exactly K·⌊steps/s⌋ exchanges;
//! * staleness bounded by O(s + K) in the naive scheme (backpressure);
//! * multi-chain convergence: R̂ → 1 for EC on the Gaussian.

use ecsgmcmc::coordinator::ec::run_ec;
use ecsgmcmc::coordinator::engine::{NativeEngine, StepKind, WorkerEngine};
use ecsgmcmc::coordinator::{
    EcConfig, EcCoordinator, NaiveConfig, NaiveCoordinator, RunOptions, TransportKind,
};
use ecsgmcmc::diagnostics::rhat;
use ecsgmcmc::math::rng::Pcg64;
use ecsgmcmc::potentials::gaussian::GaussianPotential;
use ecsgmcmc::potentials::Potential;
use ecsgmcmc::samplers::sghmc::SghmcStepper;
use ecsgmcmc::samplers::{ChainState, NoiseMode, SghmcParams};
use ecsgmcmc::testing::{gens, Prop};
use std::sync::Arc;

fn pot() -> Arc<dyn Potential> {
    Arc::new(GaussianPotential::fig1())
}

fn engines(k: usize, params: SghmcParams) -> Vec<Box<dyn WorkerEngine>> {
    (0..k)
        .map(|_| {
            Box::new(NativeEngine::new(pot(), params, StepKind::Sghmc))
                as Box<dyn WorkerEngine>
        })
        .collect()
}

#[test]
fn prop_worker_trajectories_deterministic() {
    Prop::new("ec determinism").cases(8).run(|rng| {
        let k = gens::usize_range(rng, 1, 4);
        let s = gens::usize_range(rng, 1, 5);
        let steps = gens::usize_range(rng, 10, 60);
        let alpha = gens::f64_range(rng, 0.0, 2.0);
        let seed = rng.next_u64();
        let params = SghmcParams { eps: 0.02, ..Default::default() };
        let cfg = EcConfig {
            workers: k,
            alpha,
            sync_every: s,
            steps,
            opts: RunOptions { thin: 1, ..Default::default() },
            ..Default::default()
        };
        let a = run_ec(&cfg, params, engines(k, params), seed);
        let b = run_ec(&cfg, params, engines(k, params), seed);
        for (ca, cb) in a.chains.iter().zip(&b.chains) {
            assert_eq!(ca.samples.len(), cb.samples.len());
            for (sa, sb) in ca.samples.iter().zip(&cb.samples) {
                assert_eq!(sa.1, sb.1, "worker {}", ca.worker);
            }
        }
    });
}

#[test]
fn prop_exchange_count_is_k_times_rounds() {
    Prop::new("exchange accounting").cases(12).run(|rng| {
        let k = gens::usize_range(rng, 1, 5);
        let s = gens::usize_range(rng, 1, 7);
        let steps = gens::usize_range(rng, 1, 80);
        let params = SghmcParams::default();
        let cfg = EcConfig {
            workers: k,
            alpha: 0.5,
            sync_every: s,
            steps,
            opts: RunOptions { record_samples: false, ..Default::default() },
            ..Default::default()
        };
        let r = run_ec(&cfg, params, engines(k, params), rng.next_u64());
        assert_eq!(r.metrics.exchanges as usize, k * (steps / s));
    });
}

/// Eq. (5) decomposition: with α = 0 each EC worker's trajectory equals a
/// manually-stepped decoupled chain using the same RNG stream, center
/// value irrelevant — bit-for-bit.
#[test]
fn alpha_zero_reduces_to_independent_chains_bitwise() {
    let k = 3;
    let s = 2;
    let steps = 40;
    let seed = 12345u64;
    let params = SghmcParams { eps: 0.03, ..Default::default() };
    let cfg = EcConfig {
        workers: k,
        alpha: 0.0,
        sync_every: s,
        steps,
        opts: RunOptions { thin: 1, init_sigma: 1.0, same_init: true, ..Default::default() },
        ..Default::default()
    };
    let r = run_ec(&cfg, params, engines(k, params), seed);

    // Manual replication of one worker: same init (stream 0 of seed^0x1217),
    // same rng stream (seed, 1000+w), coupling force alpha=0 against an
    // arbitrary center (the worker's own local copy — irrelevant at 0).
    let gauss = GaussianPotential::fig1();
    for w in 0..k {
        let mut init_rng = Pcg64::new(seed ^ 0x1217, 0);
        let mut state = ChainState::zeros(2);
        init_rng.fill_normal(&mut state.theta);
        // init_sigma = 1.0 multiplication is a no-op but keep parity.
        let center = state.theta.clone();
        let mut rng = Pcg64::new(seed, 1000 + w as u64);
        let mut stepper = SghmcStepper::new(params, 2);
        let mut grad = vec![0.0f32; 2];
        for t in 0..steps {
            gauss.stoch_grad(&state.theta, &mut grad, &mut rng);
            stepper.step(&mut state, &grad, Some((&center, 0.0)), &mut rng);
            let got = &r.chains[w].samples[t].1;
            assert_eq!(got, &state.theta, "worker {w} step {t} diverged");
        }
    }
}

/// Section 5: removing the noise from Eq. (6) (and M = I) yields exactly
/// the Eq. (9) deterministic updates. Simulate both by hand and compare.
#[test]
fn deterministic_limit_recovers_eq9() {
    let dim = 2;
    let eps = 0.05f32;
    let alpha = 0.4f32;
    let xi = 0.1f32; // plays eps*V in the substitution xi = V (M = I)
    let steps = 25;
    let gauss = GaussianPotential::fig1();

    // Path A: EC stepper with zero noise (noise_var = C = 0) and friction
    // chosen so eps * V = xi.
    let params = SghmcParams {
        eps: eps as f64,
        mass_inv: 1.0,
        friction: (xi / eps) as f64,
        center_friction: 0.0,
        noise_var: 0.0,
        noise_mode: NoiseMode::PaperEq6,
    };
    let mut stepper = SghmcStepper::new(params, dim);
    let mut state = ChainState { theta: vec![1.5, -0.5], p: vec![0.0, 0.0] };
    let center = vec![0.2f32, 0.1];
    let mut rng = Pcg64::seeded(1);
    let mut grad = vec![0.0f32; dim];

    // Path B: Eq. (9) by hand — theta' = theta + v; v' = v - eps*grad -
    // xi*v - eps*alpha*(theta - c), with v = eps * p (substitution from
    // Sec. 5: v = eps M p).
    let mut theta_b = vec![1.5f32, -0.5];
    let mut v_b = vec![0.0f32, 0.0];
    let mut grad_b = vec![0.0f32; dim];

    for t in 0..steps {
        gauss.full_grad(&state.theta, &mut grad);
        stepper.step(&mut state, &grad, Some((&center, alpha as f64)), &mut rng);

        gauss.full_grad(&theta_b, &mut grad_b);
        for i in 0..dim {
            let theta_old = theta_b[i];
            theta_b[i] += v_b[i];
            v_b[i] = v_b[i] - eps * eps * grad_b[i] - xi * v_b[i]
                - eps * eps * alpha * (theta_old - center[i]);
        }
        for i in 0..dim {
            assert!(
                (state.theta[i] - theta_b[i]).abs() < 1e-4,
                "step {t} dim {i}: ec={} eq9={}",
                state.theta[i],
                theta_b[i]
            );
            assert!(
                (eps * state.p[i] - v_b[i]).abs() < 1e-4,
                "step {t} dim {i}: v mismatch"
            );
        }
    }
}

#[test]
fn naive_staleness_grows_with_period_and_stays_moderate() {
    // A hard bound of O(s + K) holds per *message* under FIFO backpressure,
    // but OS time-slicing can age a preempted worker's gradient arbitrarily
    // (that is precisely the "heterogeneous machines" effect the paper
    // worries about), so we assert distributional properties instead:
    // typical staleness is small, and it increases with the broadcast
    // period s.
    let k = 4;
    let params = SghmcParams { eps: 0.02, ..Default::default() };
    let mut means = Vec::new();
    for s in [1usize, 8] {
        let cfg = NaiveConfig {
            workers: k,
            collect: 1,
            sync_every: s,
            steps: 2_000,
            synchronous: false,
            opts: RunOptions { record_samples: false, ..Default::default() },
            ..Default::default()
        };
        let r = NaiveCoordinator::new(cfg, params, pot()).run(3);
        means.push(r.metrics.mean_staleness());
    }
    assert!(means[0] < 16.0, "mean staleness at s=1 too large: {means:?}");
    assert!(
        means[1] > means[0],
        "staleness did not grow with s: {means:?}"
    );
    // Synchronous mode (covered in naive.rs unit tests) is exactly zero.
}

#[test]
fn ec_chains_mix_rhat_near_one() {
    let params = SghmcParams { eps: 0.05, ..Default::default() };
    let cfg = EcConfig {
        workers: 4,
        alpha: 1.0,
        sync_every: 2,
        steps: 20_000,
        opts: RunOptions { thin: 4, burn_in: 2_000, log_every: 10_000, ..Default::default() },
        ..Default::default()
    };
    let r = EcCoordinator::new(cfg, params, pot()).run(19);
    let per_chain: Vec<Vec<Vec<f64>>> = r
        .chains
        .iter()
        .map(|c| {
            c.samples
                .iter()
                .map(|(_, t)| t.iter().map(|&x| x as f64).collect())
                .collect()
        })
        .collect();
    let rh = rhat::max_rhat(&per_chain);
    assert!(rh < 1.1, "R-hat = {rh}");
}

/// Prop. 3.1 under the lock-free fabric: worker trajectories are racy
/// (center reads are whatever was freshest), but the stationary
/// distribution of every worker is still the posterior — pooled samples
/// must match the analytic Gaussian moments at the same tolerance as the
/// deterministic `ec_sampler_preserves_target_moments`.
#[test]
fn lockfree_ec_preserves_target_moments() {
    let cfg = EcConfig {
        workers: 4,
        alpha: 1.0,
        sync_every: 2,
        steps: 30_000,
        transport: TransportKind::LockFree,
        opts: RunOptions {
            thin: 10,
            burn_in: 3_000,
            log_every: 5_000,
            ..Default::default()
        },
        ..Default::default()
    };
    let params = SghmcParams { eps: 0.05, ..Default::default() };
    let r = EcCoordinator::new(cfg, params, pot()).run(17);
    // Center time keeps pace with worker time even when the mailboxes
    // overwrite uploads: every exchange is credited.
    assert_eq!(r.metrics.exchanges, 4 * 15_000);
    assert!(r.metrics.center_steps > 0);
    let samples = ecsgmcmc::diagnostics::to_f64_samples(r.thetas(), 2);
    let m = ecsgmcmc::diagnostics::moments(&samples);
    assert!(m.mean_error(&[0.0, 0.0]) < 0.15, "mean={:?}", m.mean);
    assert!(m.cov_error(&[1.0, 0.6, 0.6, 0.8]) < 0.3, "cov={:?}", m.cov);
}

/// Sharded lock-free EC: the center partitioned into contiguous ranges
/// steps/publishes per shard; stationarity must be unaffected.
#[test]
fn lockfree_sharded_center_stays_correct() {
    let cfg = EcConfig {
        workers: 4,
        alpha: 1.0,
        sync_every: 2,
        steps: 20_000,
        transport: TransportKind::LockFree,
        shards: 2,
        opts: RunOptions { thin: 10, burn_in: 2_000, log_every: 5_000, ..Default::default() },
        ..Default::default()
    };
    let params = SghmcParams { eps: 0.05, ..Default::default() };
    let r = EcCoordinator::new(cfg, params, pot()).run(29);
    for (_, c) in &r.center_trace {
        assert!(c.iter().all(|x| x.is_finite()));
    }
    let samples = ecsgmcmc::diagnostics::to_f64_samples(r.thetas(), 2);
    let m = ecsgmcmc::diagnostics::moments(&samples);
    assert!(m.mean_error(&[0.0, 0.0]) < 0.15, "mean={:?}", m.mean);
    assert!(m.cov_error(&[1.0, 0.6, 0.6, 0.8]) < 0.35, "cov={:?}", m.cov);
}

#[test]
fn prop_center_stays_finite_under_random_configs() {
    Prop::new("center stability").cases(10).run(|rng| {
        // alpha within the explicit-Euler stability region.
        let alpha = gens::f64_range(rng, 0.0, 3.0);
        let params = SghmcParams { eps: 0.02, ..Default::default() };
        let k = gens::usize_range(rng, 1, 4);
        let cfg = EcConfig {
            workers: k,
            alpha,
            sync_every: gens::usize_range(rng, 1, 4),
            steps: 400,
            opts: RunOptions { record_samples: false, log_every: 50, ..Default::default() },
            ..Default::default()
        };
        let r = run_ec(&cfg, params, engines(k, params), rng.next_u64());
        for (_, c) in &r.center_trace {
            assert!(c.iter().all(|x| x.is_finite()));
        }
        for c in &r.chains {
            for p in &c.u_trace {
                assert!(p.u.is_finite());
            }
        }
    });
}
