//! Seeded corpus adversary for every untrusted-input surface
//! (DESIGN.md §12): real stream and checkpoint artifacts are generated
//! in-test, then mutated ≥ 10,000 ways — truncation at every byte
//! offset, random bit flips, duplicated/reordered/spliced lines,
//! overlong numbers, pathological nesting, invalid UTF-8 — and fed to
//! the strict readers (`replay_reader`, `stream_diag`, `Snapshot::
//! parse`), the lenient salvager (`salvage_reader`), and the `top` fold.
//!
//! The contract under mutation:
//!
//! * **zero panics** on any surface (every mutant runs under
//!   `catch_unwind`);
//! * the salvager never errors on byte damage — it reports the intact
//!   prefix instead, and the prefix never exceeds the input;
//! * strict-reader rejections of stream damage name the 1-based line.
//!
//! Everything is seeded (PCG64), so a failure names the mutant and
//! replays exactly.

use ecsgmcmc::checkpoint::{CheckpointPolicy, Snapshot};
use ecsgmcmc::coordinator::ec::{run_ec, EcCheckpoint};
use ecsgmcmc::coordinator::net::frame::{self, FrameReader, Message};
use ecsgmcmc::coordinator::engine::{NativeEngine, StepKind, WorkerEngine};
use ecsgmcmc::coordinator::{EcConfig, RunOptions, TransportKind};
use ecsgmcmc::math::rng::Pcg64;
use ecsgmcmc::potentials::gaussian::GaussianPotential;
use ecsgmcmc::samplers::SghmcParams;
use ecsgmcmc::sink::replay::{replay_reader, salvage_reader, stream_diag, RunEvent};
use ecsgmcmc::sink::SinkSpec;
use ecsgmcmc::telemetry::top::TopState;
use ecsgmcmc::util::json::StreamReader;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::Arc;

fn tmp(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ecsgmcmc-corpus-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn engines(n: usize, params: SghmcParams) -> Vec<Box<dyn WorkerEngine>> {
    (0..n)
        .map(|_| {
            Box::new(NativeEngine::new(
                Arc::new(GaussianPotential::fig1()),
                params,
                StepKind::Sghmc,
            )) as Box<dyn WorkerEngine>
        })
        .collect()
}

/// A real run stream — the corpus substrate for the stream surfaces.
fn stream_artifact() -> Vec<u8> {
    let dir = tmp("stream");
    let stream = dir.join("run.jsonl");
    let cfg = EcConfig {
        workers: 2,
        alpha: 1.0,
        sync_every: 2,
        steps: 120,
        transport: TransportKind::Deterministic,
        opts: RunOptions {
            thin: 1,
            log_every: 20,
            sink: SinkSpec::Jsonl { path: stream.clone() },
            ..Default::default()
        },
        ..Default::default()
    };
    let params = SghmcParams { eps: 0.05, ..Default::default() };
    run_ec(&cfg, params, engines(2, params), 5);
    let bytes = std::fs::read(&stream).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    assert!(bytes.len() >= 4096, "stream artifact too small: {} bytes", bytes.len());
    bytes
}

/// A real checkpoint file — the corpus substrate for `Snapshot::parse`.
fn checkpoint_artifact() -> String {
    let dir = tmp("ckpt");
    let cfg = EcConfig {
        workers: 2,
        alpha: 1.0,
        sync_every: 2,
        steps: 80,
        transport: TransportKind::Deterministic,
        checkpoint: Some(EcCheckpoint {
            dir: dir.join("ckpt"),
            policy: CheckpointPolicy { every_rounds: 10, every_secs: None, keep: 100 },
        }),
        opts: RunOptions { thin: 1, log_every: 20, ..Default::default() },
        ..Default::default()
    };
    let params = SghmcParams { eps: 0.05, ..Default::default() };
    run_ec(&cfg, params, engines(2, params), 8);
    let mut snaps: Vec<PathBuf> =
        std::fs::read_dir(dir.join("ckpt")).unwrap().flatten().map(|e| e.path()).collect();
    snaps.sort();
    let text = std::fs::read_to_string(snaps.first().expect("a snapshot exists")).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    assert!(text.len() >= 1024, "checkpoint artifact too small: {} bytes", text.len());
    text
}

/// Run one mutant through every stream surface. Returns the number of
/// surface exercises. `id` names the mutant in failure messages.
fn hammer_stream(bytes: &[u8], id: &str) -> u64 {
    // Lenient surface: the salvager never errors on byte damage, never
    // claims more than it was fed, and names the line when it stops.
    let report = salvage_reader(bytes, bytes.len() as u64)
        .unwrap_or_else(|e| panic!("{id}: salvage errored on in-memory bytes: {e:#}"));
    assert!(
        report.bytes_salvaged <= bytes.len() as u64,
        "{id}: salvaged {} of {} bytes",
        report.bytes_salvaged,
        bytes.len()
    );
    assert_eq!(
        report.truncated,
        report.error.is_some() || report.bytes_salvaged < report.bytes_total,
        "{id}: inconsistent truncated flag: {report:?}"
    );
    if let Some(err) = &report.error {
        assert!(err.contains("line "), "{id}: salvage error lacks a line number: {err}");
    }

    // Strict surface: replay either succeeds or rejects naming the line.
    let replay = catch_unwind(AssertUnwindSafe(|| replay_reader(bytes)))
        .unwrap_or_else(|_| panic!("{id}: replay_reader panicked"));
    if let Err(e) = replay {
        let msg = format!("{e:#}");
        assert!(msg.contains("line "), "{id}: replay rejection lacks a line number: {msg}");
    }

    // Diagnostics surface: same contract as replay.
    let diag = catch_unwind(AssertUnwindSafe(|| stream_diag(bytes)))
        .unwrap_or_else(|_| panic!("{id}: stream_diag panicked"));
    if let Err(e) = diag {
        let msg = format!("{e:#}");
        assert!(msg.contains("line "), "{id}: diag rejection lacks a line number: {msg}");
    }

    // `top` fold surface: feed whatever decodes, render at the end.
    catch_unwind(AssertUnwindSafe(|| {
        let mut reader = StreamReader::new();
        let mut state = TopState::default();
        reader.feed(bytes);
        loop {
            let value = match reader.next_value() {
                Some(Ok(v)) => v,
                Some(Err(_)) => continue,
                None => break,
            };
            if let Ok(ev) = RunEvent::from_json(&value) {
                state.fold(&ev, &value);
            }
        }
        if let Some(Ok(value)) = reader.finish() {
            if let Ok(ev) = RunEvent::from_json(&value) {
                state.fold(&ev, &value);
            }
        }
        let _screen = state.render();
    }))
    .unwrap_or_else(|_| panic!("{id}: top fold panicked"));
    4
}

/// Run one mutant through the checkpoint parser (all-or-nothing: any
/// outcome but a panic is acceptable).
fn hammer_checkpoint(text: &str, id: &str) -> u64 {
    catch_unwind(AssertUnwindSafe(|| {
        let _ = Snapshot::parse(text);
    }))
    .unwrap_or_else(|_| panic!("{id}: Snapshot::parse panicked"));
    1
}

/// Handcrafted hostile lines spliced into streams by the mutation loop:
/// saturating numbers, null-typed fields, foreign events, pathological
/// nesting, and raw invalid UTF-8.
fn hostile_lines() -> Vec<Vec<u8>> {
    let mut lines: Vec<Vec<u8>> = vec![
        // usize saturation: step / chain overflow f64 → usize casts.
        b"{\"ev\":\"u\",\"chain\":0,\"step\":99999999999999999999999,\"t\":1,\"u\":1}".to_vec(),
        b"{\"ev\":\"sample\",\"chain\":1e300,\"t\":1,\"theta\":[1]}".to_vec(),
        // Overlong number tokens and exponent extremes.
        format!("{{\"ev\":\"u\",\"chain\":0,\"step\":1,\"t\":{},\"u\":1e999999999}}", "9".repeat(4096))
            .into_bytes(),
        b"{\"ev\":\"center\",\"t\":-1e-999999,\"theta\":[1e308,-1e308]}".to_vec(),
        // Dimension changes and degenerate theta.
        b"{\"ev\":\"sample\",\"chain\":0,\"t\":1,\"theta\":[]}".to_vec(),
        b"{\"ev\":\"sample\",\"chain\":0,\"t\":null,\"theta\":[null,null,null,null,null]}".to_vec(),
        b"{\"ev\":\"sample\",\"chain\":0,\"t\":1,\"theta\":\"not-an-array\"}".to_vec(),
        // Foreign-but-valid JSON (a checkpoint header inside a stream).
        b"{\"ev\":\"ckpt\",\"version\":1,\"scheme\":\"ec\"}".to_vec(),
        // Structurally hostile.
        b"{".repeat(200),
        b"not json at all".to_vec(),
        b"\xFF\xFE{\"ev\":\"meta\"}".to_vec(),
        b"{\"ev\":\"\xFF\xFE\"}".to_vec(),
    ];
    // Deep nesting: 100k unterminated arrays (depth guard territory) and
    // a balanced 200-deep value (over MAX_DEPTH = 128).
    lines.push(b"[".repeat(100_000));
    let mut deep = b"{\"ev\":\"telemetry\",\"t\":1,\"x\":".to_vec();
    deep.extend(b"[".repeat(200));
    deep.extend(b"1");
    deep.extend(b"]".repeat(200));
    deep.push(b'}');
    lines.push(deep);
    lines
}

#[test]
fn corpus_adversary_ten_thousand_mutants_zero_panics() {
    let stream = stream_artifact();
    let ckpt = checkpoint_artifact();
    let mut rng = Pcg64::seeded(0x00C0_FFEE);
    let mut mutants = 0u64;
    let mut exercises = 0u64;

    // ------------------------------------------------------------------
    // Class 1: truncation at EVERY byte offset of the stream. The bulk
    // of the corpus — a torn write can stop anywhere.
    // ------------------------------------------------------------------
    for cut in 0..=stream.len() {
        let slice = &stream[..cut];
        let report = salvage_reader(slice, cut as u64)
            .unwrap_or_else(|e| panic!("truncate@{cut}: salvage errored: {e:#}"));
        assert!(report.bytes_salvaged <= cut as u64, "truncate@{cut}: {report:?}");
        if let Some(err) = &report.error {
            assert!(err.contains("line "), "truncate@{cut}: {err}");
        }
        mutants += 1;
        exercises += 1;
        // The heavier strict surfaces on a stride (full diff coverage of
        // the salvager above keeps this class O(n²) instead of O(4n²)).
        if cut % 37 == 0 {
            exercises += hammer_stream(slice, &format!("truncate@{cut}"));
        }
    }
    // The untouched artifact itself is intact.
    let clean = salvage_reader(&stream[..], stream.len() as u64).unwrap();
    assert!(!clean.truncated && clean.error.is_none(), "clean artifact flagged: {clean:?}");
    assert!(clean.events > 0 && clean.samples > 0 && clean.chains == 2, "{clean:?}");

    // ------------------------------------------------------------------
    // Class 2: truncation at every offset of the checkpoint (its text is
    // ASCII JSONL, so every byte offset is a char boundary).
    // ------------------------------------------------------------------
    assert!(ckpt.is_ascii(), "checkpoint text must be ASCII for offset slicing");
    for cut in 0..=ckpt.len() {
        exercises += hammer_checkpoint(&ckpt[..cut], &format!("ckpt-truncate@{cut}"));
        // Any strict prefix must be rejected, never mis-parsed: the
        // footer line count is the integrity seal. (The one exception is
        // the cut that drops only the final newline — the content is
        // still complete.)
        if cut + 1 < ckpt.len() {
            assert!(
                Snapshot::parse(&ckpt[..cut]).is_err(),
                "ckpt-truncate@{cut}: strict prefix parsed as valid"
            );
        }
        mutants += 1;
    }
    assert!(Snapshot::parse(&ckpt).is_ok(), "clean checkpoint rejected");

    // ------------------------------------------------------------------
    // Class 3: seeded single-bit flips, stream + checkpoint.
    // ------------------------------------------------------------------
    for i in 0..3000u64 {
        let mut m = stream.clone();
        let pos = rng.below(m.len() as u64) as usize;
        let bit = rng.below(8) as u32;
        m[pos] ^= 1 << bit;
        exercises += hammer_stream(&m, &format!("bitflip#{i}@{pos}.{bit}"));
        mutants += 1;
    }
    for i in 0..2000u64 {
        let mut m = ckpt.clone().into_bytes();
        let pos = rng.below(m.len() as u64) as usize;
        let bit = rng.below(8) as u32;
        m[pos] ^= 1 << bit;
        // A flipped high bit can break UTF-8; the parser surface takes
        // &str, so damage that breaks the encoding is rejected upstream
        // by the lossy decode — exactly what the CLI's file read does.
        let text = String::from_utf8_lossy(&m);
        exercises += hammer_checkpoint(&text, &format!("ckpt-bitflip#{i}@{pos}.{bit}"));
        mutants += 1;
    }

    // ------------------------------------------------------------------
    // Class 4: line-level chaos — duplicate, swap, drop, blank-insert,
    // and splice hostile or foreign lines.
    // ------------------------------------------------------------------
    let stream_lines: Vec<&[u8]> = stream.split(|&b| b == b'\n').collect();
    let ckpt_lines: Vec<&str> = ckpt.lines().collect();
    let hostile = hostile_lines();
    for i in 0..1500u64 {
        let mut lines: Vec<Vec<u8>> = stream_lines.iter().map(|l| l.to_vec()).collect();
        for _ in 0..=rng.below(3) {
            let n = lines.len() as u64;
            match rng.below(5) {
                0 => {
                    let a = rng.below(n) as usize;
                    let dup = lines[a].clone();
                    lines.insert(a, dup);
                }
                1 => {
                    let (a, b) = (rng.below(n) as usize, rng.below(n) as usize);
                    lines.swap(a, b);
                }
                2 => {
                    lines.remove(rng.below(n) as usize);
                }
                3 => {
                    let at = rng.below(n) as usize;
                    let h = &hostile[rng.below(hostile.len() as u64) as usize];
                    lines.insert(at, h.clone());
                }
                _ => {
                    // Foreign splice: a checkpoint line inside a stream.
                    let at = rng.below(n) as usize;
                    let f = ckpt_lines[rng.below(ckpt_lines.len() as u64) as usize];
                    lines.insert(at, f.as_bytes().to_vec());
                }
            }
        }
        let mutant = lines.join(&b'\n');
        exercises += hammer_stream(&mutant, &format!("lines#{i}"));
        mutants += 1;
    }

    // ------------------------------------------------------------------
    // Class 5: every hostile line alone, and appended to a clean prefix
    // (both with and without a trailing newline — the finish() path).
    // ------------------------------------------------------------------
    for (i, h) in hostile.iter().enumerate() {
        for (j, base) in [&b""[..], &stream[..stream.len() / 2]].iter().enumerate() {
            for terminated in [false, true] {
                let mut m = base.to_vec();
                m.extend_from_slice(h);
                if terminated {
                    m.push(b'\n');
                }
                exercises += hammer_stream(&m, &format!("hostile#{i}.{j}.{terminated}"));
                mutants += 1;
            }
        }
    }

    // ------------------------------------------------------------------
    // Class 6: the overlong-line cap — an unterminated multi-megabyte
    // "line" must be abandoned with a line-naming error, not buffered
    // without bound (exercised at a small cap; the default cap's policy
    // is identical).
    // ------------------------------------------------------------------
    for i in 0..64u64 {
        let cap = 256usize;
        let mut reader = StreamReader::with_max_line(cap);
        let n = cap + 1 + rng.below(4 * cap as u64) as usize;
        let junk: Vec<u8> = (0..n).map(|_| b'a' + (rng.below(26) as u8)).collect();
        reader.feed(&junk);
        let err = match reader.next_value() {
            Some(Err(e)) => e,
            other => panic!("overlong#{i}: expected abandonment, got {other:?}"),
        };
        assert!(err.msg.contains("line 1"), "overlong#{i}: {}", err.msg);
        assert!(reader.buffered() == 0, "overlong#{i}: abandoned line still buffered");
        // Recovery: a newline ends the junk, then a clean value parses.
        reader.feed(b"\n{\"ev\":\"meta\",\"version\":1}\n");
        match reader.next_value() {
            Some(Ok(v)) => assert!(v.get("ev").is_some()),
            other => panic!("overlong#{i}: no recovery after newline: {other:?}"),
        }
        mutants += 1;
        exercises += 1;
    }

    assert!(
        mutants >= 10_000,
        "corpus too small: {mutants} mutants (need >= 10,000)"
    );
    // Sanity: the corpus actually exercised more surface calls than
    // mutants (most stream mutants hit 4 surfaces).
    assert!(exercises > mutants, "{exercises} exercises for {mutants} mutants");
    println!("corpus: {mutants} mutants, {exercises} surface exercises, zero panics");
}

// ----------------------------------------------------------------------
// The fleet wire codec (DESIGN.md §14) is an untrusted-input surface
// too: anything can connect to the center's port. Same contract as the
// stream surfaces — zero panics under ≥ 10,000 mutants, and damage is a
// clean `Err`, never an abort or unbounded allocation.
// ----------------------------------------------------------------------

/// A realistic frame stream: every message kind, including non-finite θ
/// payloads (the codec moves bits, not numbers).
fn frame_artifact() -> (Vec<u8>, usize) {
    let msgs = vec![
        Message::Hello { proto: 1, fingerprint: 0xDEAD_BEEF, seed: 42, join_gate: 7 },
        Message::Welcome {
            worker: 3,
            dim: 4,
            live: 2,
            version: 9,
            theta: vec![0.5, -1.25, f32::NAN, f32::INFINITY],
        },
        Message::Upload {
            worker: 3,
            seen_version: 9,
            theta: vec![1.0, 2.0, 3.0, f32::NEG_INFINITY],
        },
        Message::Center { version: 10, theta: vec![0.0; 16] },
        Message::Depart { fail: false, seen_version: 10, theta: Some(vec![1.0, 2.0]) },
        Message::Depart { fail: true, seen_version: 11, theta: None },
        Message::Reject { reason: "config fingerprint mismatch".into() },
    ];
    let mut bytes = Vec::new();
    for m in &msgs {
        frame::write_frame(&mut bytes, m).unwrap();
    }
    (bytes, msgs.len())
}

/// Feed one mutant to a fresh decoder and drain it. Returns (frames
/// decoded, hit an error). The decoder must never panic.
fn drain_frames(bytes: &[u8], id: &str) -> (usize, bool) {
    catch_unwind(AssertUnwindSafe(|| {
        let mut fr = FrameReader::new();
        fr.feed(bytes);
        let mut n = 0usize;
        loop {
            match fr.next_frame() {
                Ok(Some(_)) => n += 1,
                Ok(None) => return (n, false),
                Err(_) => return (n, true),
            }
        }
    }))
    .unwrap_or_else(|_| panic!("{id}: frame decoder panicked"))
}

#[test]
fn frame_decoder_corpus_zero_panics() {
    let (stream, count) = frame_artifact();
    let mut rng = Pcg64::seeded(0x0F1E_ED00);
    let mut mutants = 0u64;

    // The clean artifact decodes completely.
    let (n, err) = drain_frames(&stream, "clean");
    assert_eq!((n, err), (count, false), "clean frame stream damaged");

    // Class 1: truncation at every byte offset. A prefix decodes some
    // whole frames and then waits for more bytes or rejects — never more
    // frames than the artifact holds.
    for cut in 0..=stream.len() {
        let (n, _) = drain_frames(&stream[..cut], &format!("frame-truncate@{cut}"));
        assert!(n <= count, "frame-truncate@{cut}: {n} frames from a prefix");
        mutants += 1;
    }

    // Class 2: seeded single-bit flips. Length-field damage must bound
    // itself (MAX_FRAME), payload damage must decode or reject cleanly.
    for i in 0..6000u64 {
        let mut m = stream.clone();
        let pos = rng.below(m.len() as u64) as usize;
        m[pos] ^= 1 << (rng.below(8) as u32);
        drain_frames(&m, &format!("frame-bitflip#{i}@{pos}"));
        mutants += 1;
    }

    // Class 3: pure noise buffers — the decoder sees a hostile port scan.
    for i in 0..3000u64 {
        let n = rng.below(512) as usize;
        let junk: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        drain_frames(&junk, &format!("frame-noise#{i}"));
        mutants += 1;
    }

    // Class 4: adversarial length prefixes — claims that would allocate
    // gigabytes must reject without allocating.
    for (i, hostile) in [
        vec![0, 0, 0, 0],                            // zero-length frame
        vec![0xFF, 0xFF, 0xFF, 0xFF, 3],             // 4 GiB claim
        vec![5, 0, 0, 0, 99, 1, 2, 3, 4],            // unknown tag
        {
            // upload whose θ count field claims u32::MAX floats
            let mut b = vec![17, 0, 0, 0, 3];
            b.extend_from_slice(&3u32.to_le_bytes());
            b.extend_from_slice(&0u64.to_le_bytes());
            b.extend_from_slice(&u32::MAX.to_le_bytes());
            b
        },
    ]
    .into_iter()
    .enumerate()
    {
        let (_, err) = drain_frames(&hostile, &format!("frame-hostile#{i}"));
        assert!(err, "frame-hostile#{i}: hostile frame decoded cleanly");
        mutants += 1;
    }

    // Class 5: random chunking of the clean stream — reassembly across
    // arbitrary read boundaries loses nothing.
    for i in 0..1200u64 {
        let decoded = catch_unwind(AssertUnwindSafe(|| {
            let mut fr = FrameReader::new();
            let mut at = 0usize;
            let mut n = 0usize;
            while at < stream.len() {
                let take = 1 + rng.below(19) as usize;
                let end = (at + take).min(stream.len());
                fr.feed(&stream[at..end]);
                at = end;
                while let Ok(Some(_)) = fr.next_frame() {
                    n += 1;
                }
            }
            n
        }))
        .unwrap_or_else(|_| panic!("frame-chunk#{i}: panicked"));
        assert_eq!(decoded, count, "frame-chunk#{i}: lost frames across boundaries");
        mutants += 1;
    }

    assert!(
        mutants >= 10_000,
        "frame corpus too small: {mutants} mutants (need >= 10,000)"
    );
    println!("frame corpus: {mutants} mutants, zero panics");
}
