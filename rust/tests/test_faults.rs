//! Integration tests for deterministic fault injection + hardened
//! recovery (DESIGN.md §12):
//!
//! * the zero-cost contract: a configured-but-inactive `[faults]` plan
//!   leaves trajectories, metrics, AND the JSONL stream identical to a
//!   run with no faults section at all (wall-clock keys are the one
//!   legitimately nondeterministic field);
//! * transient checkpoint I/O faults are retried and never disturb the
//!   kill-and-resume bit-identity guarantee;
//! * a panicking worker thread folds into elastic membership as a
//!   `fail` departure and the run completes;
//! * sink write faults degrade to counted in-memory buffering and the
//!   stream stays replayable;
//! * lock-free upload drops are survived (the fault matrix across both
//!   transports);
//! * the CHAOS experiment's fast sweep produces finite posterior
//!   quality at every fault level.
//!
//! Every test flips the PROCESS-GLOBAL fault injector, so the whole
//! file serializes on one mutex and restores the disabled state through
//! a drop guard (the same discipline as `tests/test_telemetry.rs`).

use ecsgmcmc::checkpoint::{CheckpointPolicy, CheckpointStore};
use ecsgmcmc::coordinator::ec::{resume_ec, run_ec, EcCheckpoint};
use ecsgmcmc::coordinator::engine::{NativeEngine, StepKind, WorkerEngine};
use ecsgmcmc::coordinator::{EcConfig, RunOptions, RunResult, TransportKind};
use ecsgmcmc::experiments::{chaos, Scale};
use ecsgmcmc::faults::{self, FaultPlan};
use ecsgmcmc::potentials::gaussian::GaussianPotential;
use ecsgmcmc::samplers::SghmcParams;
use ecsgmcmc::sink::replay::replay_file;
use ecsgmcmc::sink::SinkSpec;
use ecsgmcmc::util::json::Json;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard};

/// The fault injector is process-global: serialize every test here.
static LOCK: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// Restores the disabled state even if the test panics, so one failure
/// can't leak an active fault plan into the next test.
struct FaultsOff;

impl Drop for FaultsOff {
    fn drop(&mut self) {
        faults::configure(None, 0);
    }
}

fn tmp(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ecsgmcmc-faults-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn engines(n: usize, params: SghmcParams) -> Vec<Box<dyn WorkerEngine>> {
    (0..n)
        .map(|_| {
            Box::new(NativeEngine::new(
                Arc::new(GaussianPotential::fig1()),
                params,
                StepKind::Sghmc,
            )) as Box<dyn WorkerEngine>
        })
        .collect()
}

/// The deterministic content of a run: θ streams per chain, Ũ values,
/// center trajectory, and the hard counters — everything but wall-clock.
type RunView = (Vec<Vec<Vec<f32>>>, Vec<Vec<(usize, f64)>>, Vec<Vec<f32>>, [u64; 4]);

fn deterministic_view(r: &RunResult) -> RunView {
    (
        r.chains.iter().map(|c| c.samples.iter().map(|(_, t)| t.clone()).collect()).collect(),
        r.chains
            .iter()
            .map(|c| c.u_trace.iter().map(|p| (p.step, p.u)).collect())
            .collect(),
        r.center_trace.iter().map(|(_, c)| c.clone()).collect(),
        [
            r.metrics.total_steps,
            r.metrics.center_steps,
            r.metrics.exchanges,
            r.metrics.samples_dropped,
        ],
    )
}

/// Parse a JSONL stream into per-line values with the wall-clock keys
/// (`t`, `steps_per_sec`, `elapsed`) removed — the rest of every event
/// must be deterministic under the deterministic transport.
fn normalized_stream(path: &Path) -> Vec<Json> {
    let text = std::fs::read_to_string(path).unwrap();
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| {
            let v = Json::parse(l).unwrap_or_else(|e| panic!("bad line {l:?}: {e}"));
            let mut m = v.as_obj().expect("stream lines are objects").clone();
            for k in ["t", "steps_per_sec", "elapsed"] {
                m.remove(k);
            }
            Json::Obj(m)
        })
        .collect()
}

// ---------------------------------------------------------------------
// Satellite: the zero-cost contract.
// ---------------------------------------------------------------------

/// A `[faults]` table with every rate at zero must be indistinguishable
/// from having no faults section at all: identical trajectories,
/// identical metrics, and an identical JSONL stream (modulo wall-clock
/// values) with none of the schema-additive fault keys present.
#[test]
fn inactive_fault_plan_is_bitwise_zero_cost() {
    let _serial = serial();
    let _off = FaultsOff;
    let dir = tmp("zerocost");
    let mk = |stream: &Path| EcConfig {
        workers: 3,
        alpha: 1.0,
        sync_every: 2,
        steps: 200,
        transport: TransportKind::Deterministic,
        opts: RunOptions {
            thin: 1,
            log_every: 50,
            sink: SinkSpec::Jsonl { path: stream.to_path_buf() },
            ..Default::default()
        },
        ..Default::default()
    };
    let params = SghmcParams { eps: 0.05, ..Default::default() };

    // Run A: no faults section at all.
    faults::configure(None, 0);
    let stream_a = dir.join("a.jsonl");
    let a = run_ec(&mk(&stream_a), params, engines(3, params), 33);

    // Run B: a `[faults]` plan is present but all-zero — the commit
    // point must leave the injector disabled.
    let plan = FaultPlan { seed: Some(7), ..Default::default() };
    assert!(!plan.is_active());
    faults::configure(Some(&plan), 123);
    assert!(!faults::enabled(), "inactive plan must not enable the injector");
    let stream_b = dir.join("b.jsonl");
    let b = run_ec(&mk(&stream_b), params, engines(3, params), 33);

    assert_eq!(deterministic_view(&a), deterministic_view(&b));
    for r in [&a, &b] {
        assert_eq!(r.metrics.faults_injected, 0);
        assert_eq!(r.metrics.ckpt_retries, 0);
        assert_eq!(r.metrics.sink_degraded, 0);
        assert_eq!(r.metrics.worker_panics, 0);
    }

    let lines_a = normalized_stream(&stream_a);
    let lines_b = normalized_stream(&stream_b);
    assert_eq!(lines_a.len(), lines_b.len(), "stream lengths diverged");
    assert_eq!(lines_a, lines_b, "streams diverged beyond wall-clock keys");
    // Schema-additive contract: fault-free streams carry no fault keys.
    for v in &lines_a {
        for k in ["faults_injected", "ckpt_retries", "sink_degraded", "worker_panics"] {
            assert!(v.get(k).is_none(), "fault-free stream leaked key {k}");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// Tentpole: hardened recovery under each fault point.
// ---------------------------------------------------------------------

/// Transient checkpoint I/O faults are absorbed by the bounded retry
/// loop: snapshots still land, the retry counter reports the noise, and
/// kill-and-resume still regenerates the exact uninterrupted stream.
#[test]
fn transient_checkpoint_faults_retry_and_preserve_resume_identity() {
    let _serial = serial();
    let _off = FaultsOff;
    let dir = tmp("ckpt-retry");
    let stream = dir.join("run.jsonl");
    let ckpt_dir = dir.join("ckpt");
    let cfg = EcConfig {
        workers: 3,
        alpha: 1.0,
        sync_every: 2,
        steps: 240,
        transport: TransportKind::Deterministic,
        checkpoint: Some(EcCheckpoint {
            dir: ckpt_dir.clone(),
            policy: CheckpointPolicy { every_rounds: 10, every_secs: None, keep: 100 },
        }),
        opts: RunOptions {
            thin: 1,
            log_every: 20,
            sink: SinkSpec::Jsonl { path: stream.clone() },
            ..Default::default()
        },
        ..Default::default()
    };
    let params = SghmcParams { eps: 0.05, ..Default::default() };
    let plan = FaultPlan { seed: Some(11), ckpt_rate: 0.3, ..Default::default() };

    faults::configure(Some(&plan), 0);
    let reference = run_ec(&cfg, params, engines(3, params), 99);
    assert!(
        reference.metrics.ckpt_retries > 0,
        "a 30% op-fault rate over dozens of checkpoint ops must force retries"
    );
    assert!(reference.metrics.faults_injected > 0);
    let replayed_ref = replay_file(&stream).unwrap();
    let ref_view = deterministic_view(&replayed_ref);

    // At least one snapshot survived the fault storm (4 attempts per
    // save across 11 interior cuts).
    let mut snaps: Vec<PathBuf> =
        std::fs::read_dir(&ckpt_dir).unwrap().flatten().map(|e| e.path()).collect();
    snaps.sort();
    assert!(!snaps.is_empty(), "no snapshot survived the injected fault storm");
    let snap = CheckpointStore::load(&snaps[0]).unwrap();
    assert!(snap.boundary > 0 && snap.boundary < cfg.steps);

    // "Kill": torn tail on the stream, then resume under the SAME fault
    // plan — injected checkpoint faults must never leak into sample
    // content, so the regenerated tail is bit-identical.
    {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new().append(true).open(&stream).unwrap();
        f.write_all(b"{\"ev\":\"sample\",\"chain\":0,\"t\":9.9,\"theta\":[0,0]}\n").unwrap();
        f.write_all(b"{\"ev\":\"sample\",\"chain\":1,\"t\":9.95,\"the").unwrap();
    }
    faults::configure(Some(&plan), 0);
    let resumed = resume_ec(&cfg, params, engines(3, params), snap).unwrap();
    assert_eq!(resumed.metrics.total_steps, reference.metrics.total_steps);
    let replayed = replay_file(&stream).unwrap();
    assert_eq!(ref_view, deterministic_view(&replayed), "resume under faults diverged");
    std::fs::remove_dir_all(&dir).ok();
}

/// A worker thread that panics at a segment boundary is folded into
/// elastic membership as a `fail` departure: the run completes, the
/// stream records the member event, and the counters say what happened.
#[test]
fn panicked_worker_folds_into_membership_and_run_completes() {
    let _serial = serial();
    let _off = FaultsOff;
    let dir = tmp("panic");
    let stream = dir.join("run.jsonl");
    let cfg = EcConfig {
        workers: 4,
        alpha: 1.0,
        sync_every: 2,
        steps: 200,
        transport: TransportKind::Deterministic,
        // Checkpoint cuts give the run interior segment boundaries — the
        // panic fault point fires at the first one (step 20), not at the
        // very end.
        checkpoint: Some(EcCheckpoint {
            dir: dir.join("ckpt"),
            policy: CheckpointPolicy { every_rounds: 10, every_secs: None, keep: 2 },
        }),
        opts: RunOptions {
            thin: 1,
            log_every: 50,
            sink: SinkSpec::Jsonl { path: stream.clone() },
            ..Default::default()
        },
        ..Default::default()
    };
    let params = SghmcParams { eps: 0.05, ..Default::default() };
    let doomed = 2usize;
    let plan = FaultPlan { seed: Some(5), panic_worker: Some(doomed), ..Default::default() };

    faults::configure(Some(&plan), 0);
    let r = run_ec(&cfg, params, engines(4, params), 17);

    assert_eq!(r.metrics.worker_panics, 1, "exactly one thread panic survived");
    assert!(r.metrics.worker_leaves >= 1, "the panic must register as a departure");
    assert!(r.metrics.faults_injected >= 1);
    assert_eq!(r.chains.len(), 4, "all chains still accounted for");
    // The surviving workers kept sampling to the end.
    assert!(r.metrics.total_steps > 0);
    assert!(r
        .chains
        .iter()
        .any(|c| c.samples.iter().any(|(_, t)| t.iter().all(|x| x.is_finite()))));

    // The stream carries the `fail` member event for the doomed worker.
    let members: Vec<(usize, String)> = normalized_stream(&stream)
        .iter()
        .filter(|v| v.get("ev").and_then(Json::as_str) == Some("member"))
        .map(|v| {
            (
                v.get("worker").and_then(Json::as_usize).unwrap(),
                v.get("kind").and_then(Json::as_str).unwrap().to_string(),
            )
        })
        .collect();
    assert!(
        members.iter().any(|(w, k)| *w == doomed && k == "fail"),
        "stream lacks the fail member event for worker {doomed}: {members:?}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Sink write faults flip the writer into degraded in-memory buffering;
/// the run completes, the degradation is counted, and the stream that
/// does land stays replayable (atomic lines, order preserved).
#[test]
fn sink_faults_degrade_to_buffering_and_stream_stays_replayable() {
    let _serial = serial();
    let _off = FaultsOff;
    let dir = tmp("sink");
    let stream = dir.join("run.jsonl");
    let cfg = EcConfig {
        workers: 3,
        alpha: 1.0,
        sync_every: 2,
        steps: 200,
        transport: TransportKind::Deterministic,
        opts: RunOptions {
            thin: 1,
            log_every: 50,
            sink: SinkSpec::Jsonl { path: stream.clone() },
            ..Default::default()
        },
        ..Default::default()
    };
    let params = SghmcParams { eps: 0.05, ..Default::default() };
    let plan = FaultPlan { seed: Some(3), sink_rate: 0.2, ..Default::default() };

    faults::configure(Some(&plan), 0);
    let r = run_ec(&cfg, params, engines(3, params), 21);

    assert!(r.metrics.sink_degraded > 0, "a 20% write-fault rate must trip degraded mode");
    assert!(r.metrics.faults_injected > 0);
    // Every line that reached disk is intact JSON and the stream as a
    // whole still replays.
    let replayed = replay_file(&stream).unwrap();
    assert_eq!(replayed.metrics.total_steps, r.metrics.total_steps);
    std::fs::remove_dir_all(&dir).ok();
}

/// The fault matrix: checkpoint + sink + panic faults on BOTH
/// transports (plus upload drops, which only exist on the lock-free
/// fabric) — every combination must carry the run to completion.
#[test]
fn fault_matrix_completes_on_both_transports() {
    let _serial = serial();
    let _off = FaultsOff;
    for (i, transport) in [TransportKind::Deterministic, TransportKind::LockFree]
        .into_iter()
        .enumerate()
    {
        let dir = tmp(&format!("matrix{i}"));
        let cfg = EcConfig {
            workers: 4,
            alpha: 1.0,
            sync_every: 2,
            steps: 200,
            transport,
            checkpoint: Some(EcCheckpoint {
                dir: dir.join("ckpt"),
                policy: CheckpointPolicy { every_rounds: 10, every_secs: None, keep: 2 },
            }),
            opts: RunOptions {
                thin: 1,
                log_every: 50,
                sink: SinkSpec::Tee(vec![
                    SinkSpec::Memory,
                    SinkSpec::Jsonl { path: dir.join("run.jsonl") },
                ]),
                ..Default::default()
            },
            ..Default::default()
        };
        let params = SghmcParams { eps: 0.05, ..Default::default() };
        let plan = FaultPlan {
            seed: Some(13 + i as u64),
            ckpt_rate: 0.2,
            sink_rate: 0.2,
            // The upload-drop point only exists on the lock-free fabric.
            drop_rate: if transport == TransportKind::LockFree { 0.2 } else { 0.0 },
            panic_worker: Some(1),
        };
        faults::configure(Some(&plan), 0);
        let r = run_ec(&cfg, params, engines(4, params), 55);
        assert_eq!(r.metrics.worker_panics, 1, "{transport:?}: panic not survived");
        assert!(r.metrics.faults_injected > 0, "{transport:?}: nothing injected");
        assert!(r.metrics.total_steps > 0, "{transport:?}: run produced no work");
        assert!(
            r.chains
                .iter()
                .all(|c| c.samples.iter().all(|(_, t)| t.iter().all(|x| x.is_finite()))),
            "{transport:?}: non-finite samples under faults"
        );
        faults::configure(None, 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}

// ---------------------------------------------------------------------
// CHAOS experiment (fast scale).
// ---------------------------------------------------------------------

/// The CHAOS sweep's fast scale: posterior quality stays finite at
/// every fault level, the baseline level injects nothing, and the
/// chaotic level reports its panic + injections.
#[test]
fn chaos_fast_sweep_produces_finite_quality_under_faults() {
    let _serial = serial();
    let _off = FaultsOff;
    let r = chaos::run(Scale::Fast, 7);
    assert_eq!(r.levels, vec![0.0, 0.3]);
    for (i, &level) in r.levels.iter().enumerate() {
        assert!(r.cov_err[i].is_finite(), "level {level}: cov err not finite");
        assert!(r.max_rhat[i].is_finite(), "level {level}: R-hat not finite");
    }
    // Baseline: injector disabled, counters silent.
    assert_eq!(r.faults_injected[0], 0);
    assert_eq!(r.ckpt_retries[0], 0);
    assert_eq!(r.sink_degraded[0], 0);
    assert_eq!(r.worker_panics[0], 0);
    // Chaotic level: faults fired and one worker died mid-run.
    assert!(r.faults_injected[1] > 0, "level 0.3 injected nothing");
    assert_eq!(r.worker_panics[1], 1, "level 0.3 must panic exactly one thread");
    let (cov, rhat) = r.to_series();
    assert_eq!(cov.xs, r.levels);
    assert_eq!(rhat.ys.len(), r.levels.len());
    assert!(!faults::enabled(), "sweep must leave the injector disabled");
}
