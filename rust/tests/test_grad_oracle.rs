//! Finite-difference gradient oracle (DESIGN.md §9 satellite): every
//! `Potential`'s `full_grad` is checked against central differences at
//! seeded random θ through the `testing::Prop` harness, so a failure
//! reports a replayable case seed. Tolerances are scaled per potential:
//! the analytic toys evaluate in f64 (tight), the data-backed models
//! accumulate in f32 over whole datasets (loose, matching the unit-level
//! spot checks).

use ecsgmcmc::data::{synth_cifar, synth_mnist};
use ecsgmcmc::math::rng::Pcg64;
use ecsgmcmc::potentials::banana::BananaPotential;
use ecsgmcmc::potentials::gaussian::GaussianPotential;
use ecsgmcmc::potentials::logreg::LogRegPotential;
use ecsgmcmc::potentials::mixture::MixturePotential;
use ecsgmcmc::potentials::nn::mlp::NativeMlp;
use ecsgmcmc::potentials::nn::resnet::NativeResNet;
use ecsgmcmc::potentials::Potential;
use ecsgmcmc::testing::{gens, Prop};

/// Probe `probes` random coordinates of ∇U at a random θ drawn from the
/// case's stream. The divisor uses the *realized* f32 perturbation
/// (`tp[i] − tm[i]`), so θ-magnitude quantization cannot bias the check.
fn check_full_grad(
    p: &dyn Potential,
    theta_scale: f32,
    h: f32,
    tol: f64,
    probes: usize,
    rng: &mut Pcg64,
) {
    let dim = p.dim();
    let padded = p.padded_dim();
    let mut theta = vec![0.0f32; padded];
    rng.fill_normal(&mut theta[..dim]);
    for t in theta[..dim].iter_mut() {
        *t *= theta_scale;
    }
    let mut grad = vec![0.0f32; padded];
    p.full_grad(&theta, &mut grad);
    for _ in 0..probes {
        let i = gens::usize_range(rng, 0, dim - 1);
        let mut tp = theta.clone();
        tp[i] += h;
        let mut tm = theta.clone();
        tm[i] -= h;
        let dh = (tp[i] - tm[i]) as f64;
        let fd = (p.full_potential(&tp) - p.full_potential(&tm)) / dh;
        let rel = (grad[i] as f64 - fd).abs() / (1.0 + fd.abs());
        assert!(
            rel < tol,
            "{}: coord {i} grad={} fd={fd} rel={rel}",
            p.name(),
            grad[i]
        );
    }
}

#[test]
fn gaussian_full_grad_matches_central_differences() {
    let p = GaussianPotential::fig1();
    Prop::new("gaussian fd oracle").cases(25).run(|rng| {
        check_full_grad(&p, 1.0, 1e-2, 1e-3, 2, rng);
    });
}

#[test]
fn mixture_full_grad_matches_central_differences() {
    let p = MixturePotential::bimodal(4.0, 1.0);
    Prop::new("mixture fd oracle").cases(25).run(|rng| {
        check_full_grad(&p, 1.0, 1e-3, 5e-3, 2, rng);
    });
}

#[test]
fn banana_full_grad_matches_central_differences() {
    let p = BananaPotential::standard();
    Prop::new("banana fd oracle").cases(25).run(|rng| {
        check_full_grad(&p, 0.5, 1e-3, 5e-3, 2, rng);
    });
}

#[test]
fn logreg_full_grad_matches_central_differences() {
    let data = synth_mnist::generate_sized(120, 5, 3, 0.1, 17);
    let (train, test) = data.split(90);
    let p = LogRegPotential::new(train, test, 15);
    Prop::new("logreg fd oracle").cases(10).run(|rng| {
        check_full_grad(&p, 0.1, 1e-2, 3e-2, 4, rng);
    });
}

#[test]
fn mlp_full_grad_matches_central_differences() {
    let data = synth_mnist::generate_sized(80, 6, 4, 0.1, 11);
    let (train, test) = data.split(60);
    let p = NativeMlp::new(train, test, 8, 2, 10);
    Prop::new("mlp fd oracle").cases(8).run(|rng| {
        check_full_grad(&p, 0.3, 1e-2, 5e-2, 4, rng);
    });
}

#[test]
fn resnet_full_grad_matches_central_differences() {
    let data = synth_cifar::generate(80, 0.2, 13);
    let (train, test) = data.split(60);
    let p = NativeResNet::new(train, test, 8, 2, 10);
    Prop::new("resnet fd oracle").cases(8).run(|rng| {
        check_full_grad(&p, 0.25, 1e-2, 5e-2, 4, rng);
    });
}
