//! Kernel-layer correctness suite (DESIGN.md §10):
//!
//! * every GEMM variant (scalar zero-skip reference, register-tiled,
//!   packed SIMD) against a naive f64 triple loop, across odd shapes
//!   including the m = 1 and k = 0 edges;
//! * NaN/Inf propagation parity with the scalar zero-skip contract;
//! * bitwise parity of the SIMD elementwise ops with their scalar twins
//!   (including NaN and −0.0 payloads);
//! * end-to-end scalar-vs-SIMD gradient parity on a real potential at
//!   1e-5 relative tolerance (the FD-oracle tolerance class).
//!
//! Dispatch-mode flips are process-global, so every test here serializes
//! on one mutex — this file is the only test binary allowed to call
//! `force_kernel`.

use ecsgmcmc::data::synth_mnist;
use ecsgmcmc::math::rng::Pcg64;
use ecsgmcmc::math::simd::{force_kernel, kernel_kind, simd_supported, KernelKind};
use ecsgmcmc::math::vecops;
use ecsgmcmc::potentials::nn::mlp::NativeMlp;
use ecsgmcmc::potentials::nn::ops;
use ecsgmcmc::potentials::Potential;
use ecsgmcmc::testing::gens;
use std::sync::Mutex;

/// Serializes dispatch-mode mutation across the tests in this binary.
static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Naive f64-accumulating oracle: C(m,n) = A(m,k) · B(k,n).
fn naive_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut c = vec![0.0f64; m * n];
    for i in 0..m {
        for l in 0..k {
            for j in 0..n {
                c[i * n + j] += a[i * k + l] as f64 * b[l * n + j] as f64;
            }
        }
    }
    c
}

/// Oracle C(k,n) = A(m,k)ᵀ · B(m,n).
fn naive_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut c = vec![0.0f64; k * n];
    for i in 0..m {
        for l in 0..k {
            for j in 0..n {
                c[l * n + j] += a[i * k + l] as f64 * b[i * n + j] as f64;
            }
        }
    }
    c
}

/// Oracle C(m,k) = A(m,n) · B(k,n)ᵀ.
fn naive_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f64> {
    let mut c = vec![0.0f64; m * k];
    for i in 0..m {
        for l in 0..k {
            for j in 0..n {
                c[i * k + l] += a[i * n + j] as f64 * b[l * n + j] as f64;
            }
        }
    }
    c
}

fn assert_close(got: &[f32], want: &[f64], tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag}: length");
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        let rel = (g as f64 - w).abs() / (1.0 + w.abs());
        assert!(rel < 1e-4, "{tag}[{i}]: got {g} want {w} (rel {rel:.2e})");
    }
}

/// Odd shapes spanning the micro-tile edges: single row, sub-tile, exact
/// tiles, ragged overhangs, and the degenerate k = 0 reduction.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 7, 5),
    (3, 5, 7),
    (5, 0, 3),
    (4, 16, 16),
    (8, 16, 32),
    (13, 9, 17),
    (17, 33, 31),
    (32, 33, 10),
    (100, 97, 3),
];

#[test]
fn all_gemm_variants_match_naive_reference() {
    let _g = lock();
    let mut rng = Pcg64::seeded(0xCAFE);
    for &(m, k, n) in SHAPES {
        let a = gens::uniform_vec(&mut rng, m * k, -1.0, 1.0);
        let b = gens::uniform_vec(&mut rng, k * n, -1.0, 1.0);
        // Dirty output buffers: every kernel must overwrite, not accumulate.
        let mut c = vec![7.0f32; m * n];
        let want = naive_nn(&a, &b, m, k, n);
        ops::gemm_nn_scalar(&a, &b, m, k, n, &mut c);
        assert_close(&c, &want, &format!("nn_scalar {m}x{k}x{n}"));
        c.fill(7.0);
        ops::gemm_nn_tiled(&a, &b, m, k, n, &mut c);
        assert_close(&c, &want, &format!("nn_tiled {m}x{k}x{n}"));
        c.fill(7.0);
        ops::gemm_nn_packed(&a, &b, m, k, n, &mut c);
        assert_close(&c, &want, &format!("nn_packed {m}x{k}x{n}"));

        // tn reads A(m,k) transposed; reuse shapes with roles (m,k)->(k,n).
        let bt = gens::uniform_vec(&mut rng, m * n, -1.0, 1.0);
        let want = naive_tn(&a, &bt, m, k, n);
        let mut c = vec![7.0f32; k * n];
        ops::gemm_tn_scalar(&a, &bt, m, k, n, &mut c);
        assert_close(&c, &want, &format!("tn_scalar {m}x{k}x{n}"));
        c.fill(7.0);
        ops::gemm_tn_tiled(&a, &bt, m, k, n, &mut c);
        assert_close(&c, &want, &format!("tn_tiled {m}x{k}x{n}"));
        c.fill(7.0);
        ops::gemm_tn_packed(&a, &bt, m, k, n, &mut c);
        assert_close(&c, &want, &format!("tn_packed {m}x{k}x{n}"));

        // nt: C(m,k) = A(m,n)·B(k,n)ᵀ — reuse (m,k,n) as (m, n_inner=k, k_out=n).
        let ant = gens::uniform_vec(&mut rng, m * k, -1.0, 1.0);
        let bnt = gens::uniform_vec(&mut rng, n * k, -1.0, 1.0);
        let want = naive_nt(&ant, &bnt, m, k, n);
        let mut c = vec![7.0f32; m * n];
        ops::gemm_nt_scalar(&ant, &bnt, m, k, n, &mut c);
        assert_close(&c, &want, &format!("nt_scalar {m}x{k}x{n}"));
        c.fill(7.0);
        ops::gemm_nt_tiled(&ant, &bnt, m, k, n, &mut c);
        assert_close(&c, &want, &format!("nt_tiled {m}x{k}x{n}"));
        c.fill(7.0);
        ops::gemm_nt_packed(&ant, &bnt, m, k, n, &mut c);
        assert_close(&c, &want, &format!("nt_packed {m}x{k}x{n}"));
    }
}

#[test]
fn k_zero_writes_zeros_in_every_variant() {
    let _g = lock();
    let (m, n) = (5usize, 3usize);
    let a: Vec<f32> = vec![];
    let b: Vec<f32> = vec![];
    for variant in ["scalar", "tiled", "packed"] {
        let mut c = vec![42.0f32; m * n];
        match variant {
            "scalar" => ops::gemm_nn_scalar(&a, &b, m, 0, n, &mut c),
            "tiled" => ops::gemm_nn_tiled(&a, &b, m, 0, n, &mut c),
            _ => ops::gemm_nn_packed(&a, &b, m, 0, n, &mut c),
        }
        assert!(c.iter().all(|&v| v == 0.0), "{variant}: k=0 must zero C, got {c:?}");
    }
}

#[test]
fn nonfinite_b_operand_poisons_every_variant() {
    let _g = lock();
    // A zero in `a` meets NaN/Inf in `b`: the scalar kernels disable the
    // zero-skip when B is non-finite, the packed kernels never skip — all
    // variants must poison the affected outputs (PR 4 contract).
    let (m, k, n) = (3usize, 4usize, 5usize);
    let mut rng = Pcg64::seeded(0xBAD);
    let mut a = gens::uniform_vec(&mut rng, m * k, -1.0, 1.0);
    a[1] = 0.0; // row 0 hits the skip path
    let mut b = gens::uniform_vec(&mut rng, k * n, -1.0, 1.0);
    b[n + 2] = f32::NAN; // b[l=1][j=2], the row the zero would skip
    b[2 * n + 4] = f32::INFINITY;
    for variant in ["scalar", "tiled", "packed"] {
        let mut c = vec![0.0f32; m * n];
        match variant {
            "scalar" => ops::gemm_nn_scalar(&a, &b, m, k, n, &mut c),
            "tiled" => ops::gemm_nn_tiled(&a, &b, m, k, n, &mut c),
            _ => ops::gemm_nn_packed(&a, &b, m, k, n, &mut c),
        }
        for i in 0..m {
            assert!(
                c[i * n + 2].is_nan(),
                "{variant}: row {i} col 2 must be NaN, got {}",
                c[i * n + 2]
            );
            assert!(
                !c[i * n + 4].is_finite(),
                "{variant}: row {i} col 4 must be non-finite, got {}",
                c[i * n + 4]
            );
        }
    }
}

/// Build elementwise inputs that exercise NaN, ±0.0, and sign edges.
fn edge_values(rng: &mut Pcg64, len: usize) -> Vec<f32> {
    let mut v = gens::uniform_vec(rng, len, -1.0, 1.0);
    for (i, x) in v.iter_mut().enumerate() {
        match i % 7 {
            0 => *x = 0.0,
            3 => *x = -0.0,
            5 => *x = f32::NAN,
            _ => {}
        }
    }
    v
}

#[test]
fn elementwise_simd_is_bit_identical_to_scalar() {
    let _g = lock();
    let mut rng = Pcg64::seeded(0x0E1E);
    for &(m, n) in &[(1usize, 1usize), (3, 5), (7, 16), (13, 33), (4, 100)] {
        let z0 = edge_values(&mut rng, m * n);
        let bias = edge_values(&mut rng, n);
        let act = edge_values(&mut rng, m * n);

        // add_bias
        let mut zs = z0.clone();
        ops::add_bias_scalar(&mut zs, &bias, m, n);
        let mut zv = z0.clone();
        force_kernel(KernelKind::Simd);
        ops::add_bias(&mut zv, &bias, m, n);
        force_kernel(KernelKind::Scalar);
        assert_bits(&zs, &zv, "add_bias");

        // relu (NaN and −0.0 must survive exactly as in scalar)
        let mut zs = z0.clone();
        ops::relu_scalar(&mut zs);
        let mut zv = z0.clone();
        force_kernel(KernelKind::Simd);
        ops::relu(&mut zv);
        force_kernel(KernelKind::Scalar);
        assert_bits(&zs, &zv, "relu");

        // relu_backward (NaN act keeps dz — `act <= 0.0` is false for NaN)
        let mut ds = z0.clone();
        ops::relu_backward_scalar(&mut ds, &act);
        let mut dv = z0.clone();
        force_kernel(KernelKind::Simd);
        ops::relu_backward(&mut dv, &act);
        force_kernel(KernelKind::Scalar);
        assert_bits(&ds, &dv, "relu_backward");

        // bias_grad: lanes are independent columns in the same row order,
        // so even this reduction is bit-identical.
        let mut dbs = vec![0.0f32; n];
        ops::bias_grad_scalar(&z0, m, n, &mut dbs);
        let mut dbv = vec![0.0f32; n];
        force_kernel(KernelKind::Simd);
        ops::bias_grad(&z0, m, n, &mut dbv);
        force_kernel(KernelKind::Scalar);
        assert_bits(&dbs, &dbv, "bias_grad");
    }
}

fn assert_bits(a: &[f32], b: &[f32], tag: &str) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{tag}[{i}]: scalar {x:?} ({:#010x}) vs simd {y:?} ({:#010x})",
            x.to_bits(),
            y.to_bits()
        );
    }
}

#[test]
fn vecops_simd_is_bit_identical_to_scalar_for_vertical_ops() {
    let _g = lock();
    let mut rng = Pcg64::seeded(0x7EC5);
    for &len in &[1usize, 7, 8, 33, 1000] {
        let x = edge_values(&mut rng, len);
        let y0 = edge_values(&mut rng, len);

        for (tag, op) in [
            ("axpy", 0usize),
            ("axpby", 1),
            ("scale", 2),
            ("add", 3),
        ] {
            let run = |mode: KernelKind, x: &[f32], y0: &[f32]| -> Vec<f32> {
                force_kernel(mode);
                let mut y = y0.to_vec();
                match op {
                    0 => vecops::axpy(0.75, x, &mut y),
                    1 => vecops::axpby(0.75, x, -1.25, &mut y),
                    2 => vecops::scale(0.375, &mut y),
                    _ => vecops::add(x, &mut y),
                }
                y
            };
            let ys = run(KernelKind::Scalar, &x, &y0);
            let yv = run(KernelKind::Simd, &x, &y0);
            force_kernel(KernelKind::Scalar);
            assert_bits(&ys, &yv, tag);
        }

        // dot / norm_sq are reductions: tolerance, not bits (and with the
        // f64 accumulators they should agree far tighter than 1e-5).
        force_kernel(KernelKind::Scalar);
        let ds = vecops::dot(&x[..len.min(33)], &y0[..len.min(33)]);
        force_kernel(KernelKind::Simd);
        let dv = vecops::dot(&x[..len.min(33)], &y0[..len.min(33)]);
        force_kernel(KernelKind::Scalar);
        if ds.is_nan() {
            assert!(dv.is_nan(), "dot: scalar NaN but simd {dv}");
        } else {
            let rel = (ds - dv).abs() / (1.0 + ds.abs());
            assert!(rel < 1e-9, "dot: scalar {ds} simd {dv} (rel {rel:.2e})");
        }
    }
}

#[test]
fn grouped_kernels_with_one_group_match_plain_gemm_bitwise() {
    let _g = lock();
    let (m, k, n) = (13usize, 9, 17);
    let mut rng = Pcg64::seeded(0x6E0);
    let a = gens::uniform_vec(&mut rng, m * k, -1.0, 1.0);
    let b = gens::uniform_vec(&mut rng, k * n, -1.0, 1.0);
    for mode in [KernelKind::Scalar, KernelKind::Simd] {
        force_kernel(mode);
        let mut plain = vec![0.0f32; m * n];
        ops::gemm_nn(&a, &b, m, k, n, &mut plain);
        let mut grouped = vec![0.0f32; m * n];
        ops::gemm_nn_grouped(&a, &[&b], m, k, n, &mut grouped);
        assert_bits(&plain, &grouped, "nn_grouped B=1");
    }
    force_kernel(KernelKind::Scalar);
}

#[test]
fn mlp_gradients_agree_across_dispatch_at_fd_oracle_tolerance() {
    let _g = lock();
    let data = synth_mnist::generate_sized(160, 8, 4, 0.1, 11);
    let (train, test) = data.split(128);
    let mlp = NativeMlp::new(train, test, 24, 2, 16);
    let mut rng = Pcg64::seeded(21);
    let theta = mlp.init_theta(0.2, &mut rng);
    let dim = mlp.padded_dim();

    // Full-batch gradient: deterministic, so any difference is kernel
    // reduction order. ISSUE tolerance class: 1e-5 relative.
    force_kernel(KernelKind::Scalar);
    let mut g_scalar = vec![0.0f32; dim];
    let u_scalar = mlp.full_grad(&theta, &mut g_scalar);
    let forced = force_kernel(KernelKind::Simd);
    let mut g_simd = vec![0.0f32; dim];
    let u_simd = mlp.full_grad(&theta, &mut g_simd);
    force_kernel(KernelKind::Scalar);
    if forced != KernelKind::Simd {
        // No SIMD on this host: the comparison is scalar-vs-scalar and
        // passes trivially; nothing more to check.
        return;
    }
    let du = (u_scalar - u_simd).abs() / (1.0 + u_scalar.abs());
    assert!(du < 1e-6, "U: scalar {u_scalar} simd {u_simd}");
    let gmax = g_scalar.iter().fold(0.0f32, |m, g| m.max(g.abs())) as f64;
    for i in 0..dim {
        let diff = (g_scalar[i] as f64 - g_simd[i] as f64).abs();
        let rel = diff / (1.0 + gmax);
        assert!(
            rel < 1e-5,
            "grad[{i}]: scalar {} simd {} (rel {rel:.2e})",
            g_scalar[i],
            g_simd[i]
        );
    }

    // Stochastic gradient: same seed ⇒ same minibatch; same tolerance.
    let mut r1 = Pcg64::seeded(33);
    let mut r2 = Pcg64::seeded(33);
    force_kernel(KernelKind::Scalar);
    let us = mlp.stoch_grad(&theta, &mut g_scalar, &mut r1);
    force_kernel(KernelKind::Simd);
    let uv = mlp.stoch_grad(&theta, &mut g_simd, &mut r2);
    force_kernel(KernelKind::Scalar);
    assert!((us - uv).abs() / (1.0 + us.abs()) < 1e-6, "stoch U: {us} vs {uv}");
    let gmax = g_scalar.iter().fold(0.0f32, |m, g| m.max(g.abs())) as f64;
    for i in 0..dim {
        let rel = (g_scalar[i] as f64 - g_simd[i] as f64).abs() / (1.0 + gmax);
        assert!(rel < 1e-5, "stoch grad[{i}] rel {rel:.2e}");
    }
}

#[test]
fn dispatch_mode_resolves_and_reports() {
    let _g = lock();
    let k = force_kernel(KernelKind::Simd);
    if simd_supported() {
        assert_eq!(k, KernelKind::Simd);
    } else {
        assert_eq!(k, KernelKind::Scalar);
    }
    assert_eq!(kernel_kind(), k);
    force_kernel(KernelKind::Scalar);
    assert_eq!(kernel_kind(), KernelKind::Scalar);
}
