//! Localhost loopback tests of the TCP fleet fabric (DESIGN.md §14):
//!
//! * parity: a K = 4 TCP fleet on the Fig. 1 Gaussian matches the
//!   analytic posterior moments at the same tolerance as the in-process
//!   lock-free fabric, and the two pooled sample sets agree;
//! * fault tolerance: killing a worker mid-run (abrupt socket drop, no
//!   DEPART) folds into a `fail` member event and the survivors
//!   complete the run;
//! * admission: a worker whose config fingerprint disagrees is rejected
//!   at the handshake with a named reason.

use ecsgmcmc::coordinator::ec::run_ec;
use ecsgmcmc::coordinator::engine::{NativeEngine, StepKind, WorkerEngine};
use ecsgmcmc::coordinator::net::frame::{self, FrameReader, Message, PROTO_VERSION};
use ecsgmcmc::coordinator::net::{self, CenterConfig, WorkerConfig};
use ecsgmcmc::coordinator::{DelayModel, EcConfig, RunOptions, RunResult, TransportKind};
use ecsgmcmc::potentials::gaussian::GaussianPotential;
use ecsgmcmc::samplers::SghmcParams;
use ecsgmcmc::sink::SinkSpec;
use std::io::Read;
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

const ALPHA: f64 = 1.0;
const SYNC: usize = 2;

fn params() -> SghmcParams {
    SghmcParams { eps: 0.05, ..Default::default() }
}

fn engine() -> Box<dyn WorkerEngine> {
    Box::new(NativeEngine::new(
        Arc::new(GaussianPotential::fig1()),
        params(),
        StepKind::Sghmc,
    ))
}

fn tmp(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ecsgmcmc-net-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn center_config(k: usize, steps: usize, seed: u64, opts: RunOptions) -> CenterConfig {
    CenterConfig {
        workers: k,
        alpha: ALPHA,
        sync_every: SYNC,
        steps,
        shards: 1,
        dim: 2,
        live: 2,
        seed,
        params: params(),
        opts,
        delay: DelayModel::default(),
        staleness_bound: None,
        checkpoint: None,
        resume: false,
        idle_timeout: Duration::from_secs(30),
    }
}

fn worker_config(addr: &str, k: usize, steps: usize, seed: u64, opts: RunOptions) -> WorkerConfig {
    let fp = net::fleet_fingerprint(k, ALPHA, SYNC, steps, 1, 2, 2, None);
    WorkerConfig {
        connect: addr.to_string(),
        seed,
        steps,
        sync_every: SYNC,
        alpha: ALPHA,
        opts,
        delay: DelayModel::default(),
        fingerprint_hash: net::fingerprint_hash(&fp),
        join_gate: 0,
        retries: 5,
    }
}

/// Serve a K-founder fleet on an ephemeral loopback port and run every
/// worker as a process-local thread (same code path as a real remote
/// process — the socket does not care).
fn run_fleet(
    k: usize,
    steps: usize,
    seed: u64,
    opts: RunOptions,
    center_opts: RunOptions,
) -> (RunResult, Vec<RunResult>) {
    let listener = net::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let ccfg = center_config(k, steps, seed, center_opts);
    let center = std::thread::spawn(move || net::run_center_on(listener, ccfg).unwrap());
    let workers: Vec<_> = (0..k)
        .map(|_| {
            let wcfg = worker_config(&addr, k, steps, seed, opts.clone());
            std::thread::spawn(move || net::run_worker(&wcfg, engine()).unwrap())
        })
        .collect();
    let worker_results = workers.into_iter().map(|h| h.join().unwrap()).collect();
    (center.join().unwrap(), worker_results)
}

/// Pool every worker's retained samples into one (time, θ) list.
fn pooled(workers: &[RunResult]) -> Vec<Vec<f64>> {
    workers
        .iter()
        .flat_map(|r| r.samples.iter())
        .map(|(_, t)| t.iter().map(|&x| x as f64).collect())
        .collect()
}

#[test]
fn tcp_fleet_matches_lockfree_moments_on_fig1() {
    let k = 4;
    let steps = 30_000;
    let seed = 17;
    let opts = RunOptions { thin: 10, burn_in: 3_000, log_every: 5_000, ..Default::default() };
    let (center, workers) =
        run_fleet(k, steps, seed, opts.clone(), RunOptions { log_every: 5_000, ..Default::default() });

    // Exchange accounting survives the wire: every upload is credited.
    let sent: u64 = workers.iter().map(|r| r.metrics.exchanges).sum();
    assert_eq!(sent, (k * (steps / SYNC)) as u64);
    assert_eq!(center.metrics.exchanges, sent);
    assert!(center.metrics.center_steps > 0);
    // All founders departed cleanly at their horizon.
    assert_eq!(center.metrics.worker_leaves, k as u64);
    assert_eq!(center.metrics.stale_rejects, 0);
    for (_, c) in &center.center_trace {
        assert!(c.iter().all(|x| x.is_finite()));
    }

    // Posterior moments at the lock-free fabric's own tolerance.
    let samples = pooled(&workers);
    assert!(samples.len() > 5_000, "only {} pooled samples", samples.len());
    let m = ecsgmcmc::diagnostics::moments(&samples);
    assert!(m.mean_error(&[0.0, 0.0]) < 0.15, "mean={:?}", m.mean);
    assert!(m.cov_error(&[1.0, 0.6, 0.6, 0.8]) < 0.3, "cov={:?}", m.cov);

    // And head-to-head against the in-process lock-free run with the same
    // experiment: both are noisy estimates of the same posterior.
    let cfg = EcConfig {
        workers: k,
        alpha: ALPHA,
        sync_every: SYNC,
        steps,
        transport: TransportKind::LockFree,
        opts,
        ..Default::default()
    };
    let engines: Vec<Box<dyn WorkerEngine>> = (0..k).map(|_| engine()).collect();
    let lf = run_ec(&cfg, params(), engines, seed);
    let lf_samples = ecsgmcmc::diagnostics::to_f64_samples(lf.thetas(), 2);
    let lm = ecsgmcmc::diagnostics::moments(&lf_samples);
    for i in 0..2 {
        assert!(
            (m.mean[i] - lm.mean[i]).abs() < 0.2,
            "tcp mean {:?} vs lockfree {:?}",
            m.mean,
            lm.mean
        );
    }
    for i in 0..4 {
        assert!(
            (m.cov[i] - lm.cov[i]).abs() < 0.4,
            "tcp cov {:?} vs lockfree {:?}",
            m.cov,
            lm.cov
        );
    }
}

/// Speak just enough of the wire protocol to impersonate a worker, then
/// vanish without a DEPART — indistinguishable from SIGKILL as far as
/// the center can tell.
fn killed_worker(addr: &str, k: usize, steps: usize, seed: u64) {
    let fp = net::fleet_fingerprint(k, ALPHA, SYNC, steps, 1, 2, 2, None);
    let mut stream = TcpStream::connect(addr).unwrap();
    frame::write_frame(
        &mut stream,
        &Message::Hello {
            proto: PROTO_VERSION,
            fingerprint: net::fingerprint_hash(&fp),
            seed,
            join_gate: 0,
        },
    )
    .unwrap();
    let mut fr = FrameReader::new();
    let mut tmp = [0u8; 4096];
    let (mut seen, worker) = loop {
        if let Some(msg) = fr.next_frame().unwrap() {
            match msg {
                Message::Welcome { worker, version, .. } => break (version, worker),
                other => panic!("expected WELCOME, got {other:?}"),
            }
        }
        let n = stream.read(&mut tmp).unwrap();
        assert!(n > 0, "center closed during handshake");
        fr.feed(&tmp[..n]);
    };
    for _ in 0..5 {
        frame::write_frame(
            &mut stream,
            &Message::Upload { worker, seen_version: seen, theta: vec![0.1, -0.2] },
        )
        .unwrap();
        seen += 1;
        std::thread::sleep(Duration::from_millis(5));
    }
    // Dropping the stream here sends no DEPART: the center's reader sees
    // a dead socket and must fold this slot into a `fail` event.
}

#[test]
fn killing_a_worker_folds_into_fail_and_survivors_complete() {
    let k = 3;
    let steps = 6_000;
    let seed = 23;
    let dir = tmp("kill");
    let stream_path = dir.join("center.jsonl");
    let opts = RunOptions { thin: 10, burn_in: 500, log_every: 2_000, ..Default::default() };
    let center_opts = RunOptions {
        log_every: 2_000,
        sink: SinkSpec::Jsonl { path: stream_path.clone() },
        ..Default::default()
    };

    let listener = net::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let ccfg = center_config(k, steps, seed, center_opts);
    let center = std::thread::spawn(move || net::run_center_on(listener, ccfg).unwrap());

    let survivors: Vec<_> = (0..k - 1)
        .map(|_| {
            let wcfg = worker_config(&addr, k, steps, seed, opts.clone());
            std::thread::spawn(move || net::run_worker(&wcfg, engine()).unwrap())
        })
        .collect();
    killed_worker(&addr, k, steps, seed);

    let survivor_results: Vec<RunResult> =
        survivors.into_iter().map(|h| h.join().unwrap()).collect();
    let center_result = center.join().unwrap();

    // Survivors ran to their full horizon despite the casualty.
    for r in &survivor_results {
        assert_eq!(r.metrics.total_steps, steps as u64);
        assert_eq!(r.metrics.exchanges, (steps / SYNC) as u64);
    }
    // All three members are accounted for: two leaves plus one fail.
    assert_eq!(center_result.metrics.worker_leaves, k as u64);
    assert!(center_result.metrics.center_steps > 0);

    // The stream records the membership transition as a fail, not a leave.
    let text = std::fs::read_to_string(&stream_path).unwrap();
    assert!(
        text.lines().any(|l| l.contains("\"ev\":\"member\"") && l.contains("\"kind\":\"fail\"")),
        "no fail member event in the center stream"
    );
    assert!(
        text.lines().any(|l| l.contains("\"ev\":\"member\"") && l.contains("\"kind\":\"leave\"")),
        "no leave member events in the center stream"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mismatched_fingerprint_is_rejected_at_the_handshake() {
    let k = 1;
    let steps = 200;
    let seed = 31;
    let listener = net::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let mut ccfg = center_config(k, steps, seed, RunOptions::default());
    ccfg.idle_timeout = Duration::from_secs(2);
    let center = std::thread::spawn(move || net::run_center_on(listener, ccfg).unwrap());

    // A worker whose config drifted (different sync_every → different
    // fingerprint) must be turned away with a reason, not silently join
    // a different experiment.
    let mut wcfg = worker_config(&addr, k, steps, seed, RunOptions::default());
    wcfg.fingerprint_hash ^= 1;
    wcfg.retries = 0;
    let err = net::run_worker(&wcfg, engine()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("rejected"), "unexpected error: {msg}");
    assert!(msg.contains("fingerprint"), "rejection lacks the reason: {msg}");

    // The center, having never admitted anyone, gives up at its idle
    // timeout instead of serving forever.
    let center_result = center.join().unwrap();
    assert_eq!(center_result.metrics.worker_leaves, 0);
}
