//! Observatory end-to-end: enabling the fleet observatory must not
//! perturb the sampled trajectories (the observer only *reads* sampler
//! state), the HTTP endpoints must serve parse-valid exposition while a
//! run is live, health events must land in the stream schema-additively
//! (v4), and the offline `report` harness must reproduce `replay
//! --diag`'s convergence numbers bit-for-bit — including against the
//! committed miniature golden stream.

use ecsgmcmc::coordinator::{EcConfig, EcCoordinator, RunOptions, RunResult};
use ecsgmcmc::observe;
use ecsgmcmc::potentials::gaussian::GaussianPotential;
use ecsgmcmc::samplers::SghmcParams;
use ecsgmcmc::sink::{replay, SinkSpec};
use ecsgmcmc::util::json::Json;
use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// The observatory switches are process-global; every test that flips
/// them runs under this lock and restores "off" on exit.
static LOCK: Mutex<()> = Mutex::new(());

struct ObserveOff;
impl Drop for ObserveOff {
    fn drop(&mut self) {
        observe::configure(false, "").ok();
    }
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ecsgmcmc-observe-{name}-{}.jsonl", std::process::id()))
}

fn ec_run(sink: SinkSpec, steps: usize, seed: u64) -> RunResult {
    let cfg = EcConfig {
        workers: 4,
        alpha: 1.0,
        sync_every: 2,
        steps,
        opts: RunOptions {
            thin: 2,
            burn_in: 50,
            log_every: 100,
            sink,
            ..Default::default()
        },
        ..Default::default()
    };
    EcCoordinator::new(
        cfg,
        SghmcParams { eps: 0.05, ..Default::default() },
        Arc::new(GaussianPotential::fig1()),
    )
    .run(seed)
}

fn assert_same_trajectories(a: &RunResult, b: &RunResult) {
    assert_eq!(a.chains.len(), b.chains.len());
    for (ca, cb) in a.chains.iter().zip(&b.chains) {
        assert_eq!(ca.worker, cb.worker);
        assert_eq!(ca.samples, cb.samples, "chain {} samples", ca.worker);
        assert_eq!(ca.u_trace.len(), cb.u_trace.len(), "chain {} u trace", ca.worker);
        for (ua, ub) in ca.u_trace.iter().zip(&cb.u_trace) {
            assert_eq!(ua.step, ub.step);
            assert_eq!(ua.u, ub.u);
        }
    }
    assert_eq!(a.center_trace, b.center_trace);
    assert_eq!(a.metrics.exchanges, b.metrics.exchanges);
    assert_eq!(a.metrics.total_steps, b.metrics.total_steps);
}

fn http_get(addr: SocketAddr, path: &str) -> (u16, String) {
    let mut s = TcpStream::connect_timeout(&addr, Duration::from_secs(2)).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
    s.set_write_timeout(Some(Duration::from_secs(2))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: observatory\r\nConnection: close\r\n\r\n").unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    let code = raw.split_whitespace().nth(1).and_then(|c| c.parse().ok()).unwrap_or(0);
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (code, body)
}

#[test]
fn fig1_run_is_bit_identical_with_observatory_on() {
    let _guard = LOCK.lock().unwrap();
    let _restore = ObserveOff;
    observe::configure(false, "").unwrap();
    let off = ec_run(SinkSpec::Memory, 600, 7);

    observe::configure(true, "127.0.0.1:0").unwrap().expect("bound");
    let on = ec_run(SinkSpec::Memory, 600, 7);
    let snap = observe::shared().expect("shared cell").snapshot();
    observe::configure(false, "").unwrap();

    assert_same_trajectories(&off, &on);
    // The run actually published into the snapshot cell on the way.
    assert!(snap.started && snap.finished, "driver published: {snap:?}");
    assert_eq!(snap.scheme, "ec");
    assert_eq!(snap.workers_total, 4);
    assert_eq!(snap.center_steps, on.metrics.center_steps);
}

#[test]
fn observed_stream_adds_only_health_events() {
    let _guard = LOCK.lock().unwrap();
    let _restore = ObserveOff;
    observe::configure(false, "").unwrap();
    let path_off = tmp("stream-off");
    let path_on = tmp("stream-on");

    ec_run(SinkSpec::Jsonl { path: path_off.clone() }, 400, 11);
    observe::configure(true, "127.0.0.1:0").unwrap();
    ec_run(SinkSpec::Jsonl { path: path_on.clone() }, 400, 11);
    observe::configure(false, "").unwrap();

    // Replay ignores the health annotations: both streams reconstruct
    // the same run.
    let off = replay::replay_file(&path_off).unwrap();
    let on = replay::replay_file(&path_on).unwrap();
    assert_same_trajectories(&off, &on);

    // Byte-level: stripping `health` lines from the observed stream
    // leaves the unobserved stream, except the metrics event whose
    // elapsed/steps_per_sec are wall-clock (compare those structurally).
    let text_off = std::fs::read_to_string(&path_off).unwrap();
    let text_on = std::fs::read_to_string(&path_on).unwrap();
    let lines_off: Vec<&str> = text_off.lines().collect();
    let lines_on: Vec<&str> =
        text_on.lines().filter(|l| !l.contains("\"ev\":\"health\"")).collect();
    assert!(text_on.lines().any(|l| l.contains("\"ev\":\"health\"")), "health events present");
    assert_eq!(lines_off.len(), lines_on.len(), "same events modulo health");
    for (a, b) in lines_off.iter().zip(&lines_on) {
        if a.contains("\"ev\":\"metrics\"") {
            let (va, vb) = (Json::parse(a).unwrap(), Json::parse(b).unwrap());
            for key in ["total_steps", "center_steps", "exchanges", "mean_staleness"] {
                assert_eq!(
                    va.get(key).and_then(Json::as_f64),
                    vb.get(key).and_then(Json::as_f64),
                    "metrics field {key}"
                );
            }
        } else {
            assert_eq!(a, b, "non-metrics lines are byte-identical");
        }
    }

    // The health events parse as stream v4 events and `top` renders them.
    let mut health = 0usize;
    let file = std::fs::File::open(&path_on).unwrap();
    replay::scan_stream(file, |ev| {
        if let replay::RunEvent::Health { json, .. } = ev {
            health += 1;
            assert!(json.get("status").and_then(Json::as_str).is_some());
            assert!(json.get("workers_active").is_some());
        }
        Ok(())
    })
    .unwrap();
    assert!(health > 0);
    let rendered = ecsgmcmc::telemetry::top::top_once(&path_on).unwrap();
    assert!(rendered.contains("health:"), "top shows the health line:\n{rendered}");

    std::fs::remove_file(&path_off).ok();
    std::fs::remove_file(&path_on).ok();
}

#[test]
fn endpoints_serve_valid_exposition_during_a_live_run() {
    let _guard = LOCK.lock().unwrap();
    let _restore = ObserveOff;
    observe::configure(false, "").unwrap();
    let baseline = ec_run(SinkSpec::Memory, 2000, 17);

    let addr = observe::configure(true, "127.0.0.1:0").unwrap().expect("bound");
    let run = std::thread::spawn(move || ec_run(SinkSpec::Memory, 2000, 17));
    // Scrape while the run is live (and at least once after it ends —
    // the final publish survives until reconfiguration).
    let mut mid_run_scrapes = 0usize;
    while !run.is_finished() {
        let (code, body) = http_get(addr, "/metrics");
        assert_eq!(code, 200);
        observe::prometheus::validate_exposition(&body)
            .unwrap_or_else(|e| panic!("invalid exposition: {e}\n{body}"));
        mid_run_scrapes += 1;
        std::thread::sleep(Duration::from_millis(2));
    }
    let observed = run.join().unwrap();

    let (code, body) = http_get(addr, "/metrics");
    assert_eq!(code, 200);
    observe::prometheus::validate_exposition(&body).expect("final exposition parses");
    assert!(body.contains("ecsgmcmc_up 1"), "{body}");
    assert!(body.contains("ecsgmcmc_center_steps_total"), "{body}");
    assert!(body.contains("ecsgmcmc_health_status"), "{body}");

    let (code, body) = http_get(addr, "/status");
    assert_eq!(code, 200);
    let v = Json::parse(body.trim()).expect("status is valid JSON");
    assert_eq!(v.get("scheme").and_then(Json::as_str), Some("ec"));
    assert_eq!(v.get("finished"), Some(&Json::Bool(true)));
    assert!(v.path(&["health", "status"]).is_some());

    let (code, body) = http_get(addr, "/healthz");
    assert_eq!(code, 200, "finished healthy run stays ready: {body}");
    observe::configure(false, "").unwrap();

    // Scraping concurrently changed nothing about the dynamics.
    assert_same_trajectories(&baseline, &observed);
    assert!(mid_run_scrapes > 0 || observed.elapsed < 1.0, "scraped during the run");
}

#[test]
fn report_matches_replay_diag_on_a_real_observed_stream() {
    let _guard = LOCK.lock().unwrap();
    let _restore = ObserveOff;
    let stream = tmp("report");
    observe::configure(true, "127.0.0.1:0").unwrap();
    ec_run(SinkSpec::Jsonl { path: stream.clone() }, 400, 13);
    observe::configure(false, "").unwrap();

    let dir = std::env::temp_dir()
        .join(format!("ecsgmcmc-observe-reportdir-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let report = observe::report::write_report(&stream, &dir.join("report.md")).unwrap();
    let (diag, _) =
        replay::stream_diag(std::fs::File::open(&stream).unwrap()).unwrap();
    assert_eq!(report.max_rhat.to_bits(), diag.max_rhat.to_bits(), "same R-hat bits");
    assert_eq!(report.min_ess.to_bits(), diag.min_ess.to_bits(), "same ESS bits");
    assert_eq!(report.chains, diag.chains);

    let md = std::fs::read_to_string(&report.markdown).unwrap();
    assert!(md.contains("## Health"), "observed stream reports health:\n{md}");
    assert!(md.contains("## Convergence"));

    std::fs::remove_file(&stream).ok();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn golden_report_for_the_committed_miniature_stream() {
    // No process-global state involved: pure file-in, file-out.
    let data = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/data");
    let stream = data.join("mini_run.jsonl");
    let golden = data.join("mini_run_report.md");
    let dir = std::env::temp_dir()
        .join(format!("ecsgmcmc-observe-golden-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let report = observe::report::write_report(&stream, &dir.join("mini_run_report.md")).unwrap();
    let got = std::fs::read_to_string(&report.markdown).unwrap();
    let want = std::fs::read_to_string(&golden).unwrap();
    if got != want {
        for (i, (g, w)) in got.lines().zip(want.lines()).enumerate() {
            assert_eq!(g, w, "first divergence at golden line {}", i + 1);
        }
        assert_eq!(got, want, "generated report drifted from {golden:?}");
    }

    // The JSON sibling carries the same facts machine-readably.
    let json = std::fs::read_to_string(&report.json).unwrap();
    let v = Json::parse(json.trim()).unwrap();
    assert_eq!(v.get("samples").and_then(Json::as_usize), Some(4));
    assert_eq!(v.get("final_health").and_then(Json::as_str), Some("degraded"));
    assert_eq!(v.get("health_events").and_then(Json::as_usize), Some(2));
    assert!(
        matches!(v.path(&["diag", "max_rhat"]), Some(Json::Null)),
        "4-draw chains are too short for split-R-hat"
    );
    assert_eq!(v.path(&["diag", "min_ess"]).and_then(Json::as_f64), Some(4.0));

    std::fs::remove_dir_all(&dir).ok();
}
