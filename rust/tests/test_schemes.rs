//! Cross-scheme integration tests: every parallelization scheme samples
//! the same analytic target and must agree with it (Prop. 3.1 for EC, the
//! standard guarantees for the others), plus end-to-end runs of the
//! experiment harnesses at smoke scale.

use ecsgmcmc::config::RunConfig;
use ecsgmcmc::coordinator::engine::{NativeEngine, StepKind, WorkerEngine};
use ecsgmcmc::coordinator::single::run_single;
use ecsgmcmc::coordinator::{
    EcConfig, EcCoordinator, IndependentCoordinator, NaiveConfig, NaiveCoordinator, RunOptions,
};
use ecsgmcmc::diagnostics::{ess, ks, moments, to_f64_samples};
use ecsgmcmc::experiments::{easgd_cmp, fig1, fig2, Scale};
use ecsgmcmc::potentials::banana::BananaPotential;
use ecsgmcmc::potentials::gaussian::GaussianPotential;
use ecsgmcmc::potentials::mixture::MixturePotential;
use ecsgmcmc::potentials::Potential;
use ecsgmcmc::samplers::hmc::HmcSampler;
use ecsgmcmc::samplers::SghmcParams;
use std::sync::Arc;

const TARGET_MEAN: [f64; 2] = [0.0, 0.0];
const TARGET_COV: [f64; 4] = [1.0, 0.6, 0.6, 0.8];

fn gauss() -> Arc<dyn Potential> {
    Arc::new(GaussianPotential::fig1())
}

fn params() -> SghmcParams {
    SghmcParams { eps: 0.05, ..Default::default() }
}

fn check_moments<'a, I: IntoIterator<Item = &'a [f32]>>(
    label: &str,
    thetas: I,
    tol_mean: f64,
    tol_cov: f64,
) {
    let samples = to_f64_samples(thetas, 2);
    let m = moments(&samples);
    assert!(
        m.mean_error(&TARGET_MEAN) < tol_mean,
        "{label}: mean {:?}",
        m.mean
    );
    assert!(
        m.cov_error(&TARGET_COV) < tol_cov,
        "{label}: cov {:?}",
        m.cov
    );
}

fn sample_opts(burn: usize) -> RunOptions {
    RunOptions { thin: 5, burn_in: burn, log_every: 10_000, ..Default::default() }
}

#[test]
fn all_schemes_sample_the_same_gaussian() {
    // 1. Sequential SGHMC.
    let engine = Box::new(NativeEngine::new(gauss(), params(), StepKind::Sghmc));
    let r = run_single(engine, 60_000, sample_opts(3_000), 1);
    check_moments("sghmc", r.thetas(), 0.12, 0.25);

    // 2. Independent chains.
    let engines: Vec<Box<dyn WorkerEngine>> = (0..4)
        .map(|_| {
            Box::new(NativeEngine::new(gauss(), params(), StepKind::Sghmc))
                as Box<dyn WorkerEngine>
        })
        .collect();
    let r = IndependentCoordinator::new(25_000, sample_opts(3_000)).run(engines, 2);
    check_moments("independent", r.thetas(), 0.12, 0.25);

    // 3. Synchronous parallel (s=1, O=K).
    let r = NaiveCoordinator::new(
        NaiveConfig::synchronous(4, 40_000, sample_opts(3_000)),
        params(),
        gauss(),
    )
    .run(3);
    check_moments("synchronous", r.thetas(), 0.12, 0.25);

    // 4. Naive async with mild staleness. Stale gradients act as a
    // feedback delay, so the step size must be well inside the stable
    // region (eps * mean_staleness * curvature << 1); at eps = 0.05 the
    // delayed dynamics visibly inflate the covariance — which is exactly
    // the Sec. 2 phenomenon (see bench_staleness). Sample at eps = 0.01.
    let r = NaiveCoordinator::new(
        NaiveConfig {
            workers: 4,
            collect: 1,
            sync_every: 2,
            steps: 60_000,
            synchronous: false,
            opts: sample_opts(5_000),
            ..Default::default()
        },
        SghmcParams { eps: 0.01, ..Default::default() },
        gauss(),
    )
    .run(4);
    check_moments("naive_async(s=2)", r.thetas(), 0.15, 0.35);

    // 5. EC-SGHMC.
    let r = EcCoordinator::new(
        EcConfig {
            workers: 4,
            alpha: 1.0,
            sync_every: 2,
            steps: 25_000,
            opts: sample_opts(3_000),
            ..Default::default()
        },
        params(),
        gauss(),
    )
    .run(5);
    check_moments("ec_sghmc", r.thetas(), 0.15, 0.3);
}

#[test]
fn ec_marginals_pass_ks_against_analytic_normal() {
    let r = EcCoordinator::new(
        EcConfig {
            workers: 4,
            alpha: 0.5,
            sync_every: 2,
            steps: 30_000,
            opts: RunOptions { thin: 20, burn_in: 4_000, log_every: 10_000, ..Default::default() },
            ..Default::default()
        },
        params(),
        gauss(),
    )
    .run(7);
    let samples = to_f64_samples(r.thetas(), 2);
    // Marginal 0 is N(0, 1); use ESS-deflated n for the p-value.
    let xs: Vec<f64> = samples.iter().map(|s| s[0]).collect();
    let d = ks::ks_statistic(&xs, 0.0, 1.0);
    let n_eff = ess::ess(&xs);
    let p = ks::ks_pvalue(d, n_eff);
    assert!(p > 1e-3, "KS reject: d={d:.4} n_eff={n_eff:.0} p={p:.2e}");
}

#[test]
fn ec_agrees_with_exact_hmc_on_banana() {
    // Gold-standard cross-check on a non-Gaussian target: compare EC
    // moments against exact-MH HMC moments on the short-valley banana
    // (the classic sigma_x^2 = 10 valley needs far more steps than a test
    // budget allows; curvature structure is identical).
    let banana = Arc::new(BananaPotential::tight());
    let mut hmc = HmcSampler::new(0.08, 10);
    let mut rng = ecsgmcmc::math::rng::Pcg64::seeded(8);
    let mut theta = vec![1.0f32, 1.0];
    let mut hmc_samples = Vec::new();
    for t in 0..60_000 {
        hmc.transition(banana.as_ref(), &mut theta, &mut rng);
        if t >= 6_000 && t % 4 == 0 {
            hmc_samples.push(vec![theta[0] as f64, theta[1] as f64]);
        }
    }
    assert!(hmc.acceptance_rate() > 0.7, "hmc accept {}", hmc.acceptance_rate());
    let hmc_m = moments(&hmc_samples);

    // Matched friction/noise keep the stationary distribution exact; the
    // curvature near |x| ~ 2 demands a small step.
    let ec_params =
        SghmcParams { eps: 0.01, friction: 3.0, noise_var: 3.0, ..Default::default() };
    let r = EcCoordinator::new(
        EcConfig {
            workers: 4,
            alpha: 0.3,
            sync_every: 2,
            steps: 120_000,
            opts: RunOptions { thin: 10, burn_in: 12_000, log_every: 30_000, ..Default::default() },
            ..Default::default()
        },
        ec_params,
        banana.clone() as Arc<dyn Potential>,
    )
    .run(9);
    let ec_m = moments(&to_f64_samples(r.thetas(), 2));
    // SGHMC at finite eps carries discretization bias and mixes slowly
    // along the curved valley, so agreement is approximate: means within a
    // few tenths, variance scale within 2x (the y marginal is chi^2-like
    // heavy-tailed, hence the wider band there).
    assert!(
        (ec_m.mean[0] - hmc_m.mean[0]).abs() < 0.35,
        "mean x: ec {:?} hmc {:?}",
        ec_m.mean,
        hmc_m.mean
    );
    assert!(
        (ec_m.mean[1] - hmc_m.mean[1]).abs() < 0.9,
        "mean y: ec {:?} hmc {:?}",
        ec_m.mean,
        hmc_m.mean
    );
    let ratio = ec_m.cov[0] / hmc_m.cov[0];
    assert!((0.4..2.2).contains(&ratio), "x-var ratio {ratio} (ec {:?} hmc {:?})", ec_m.cov, hmc_m.cov);
}

#[test]
fn mixture_modes_both_visited_by_ec() {
    let mix = Arc::new(MixturePotential::bimodal(3.0, 0.5));
    let r = EcCoordinator::new(
        EcConfig {
            workers: 4,
            alpha: 0.2, // weak coupling: let chains split across modes
            sync_every: 4,
            steps: 30_000,
            opts: RunOptions {
                thin: 10,
                burn_in: 2_000,
                log_every: 10_000,
                same_init: false,
                init_sigma: 2.0,
                ..Default::default()
            },
            ..Default::default()
        },
        SghmcParams { eps: 0.03, ..Default::default() },
        mix as Arc<dyn Potential>,
    )
    .run(11);
    let samples = to_f64_samples(r.thetas(), 2);
    let left = samples.iter().filter(|s| s[0] < 0.0).count();
    let frac = left as f64 / samples.len() as f64;
    assert!(
        (0.15..=0.85).contains(&frac),
        "mode coverage unbalanced: left frac {frac}"
    );
}

#[test]
fn fig1_harness_shapes() {
    let r = fig1::run(50, 2);
    assert_eq!(r.sghmc_traces.len(), 2);
    assert_eq!(r.ec_traces.len(), 4);
    assert!(r.mean_potential.iter().all(|u| u.is_finite()));
}

#[test]
fn fig2_fast_run_produces_descending_nll() {
    let series = fig2::run_mnist(Scale::Fast, 3);
    assert_eq!(series.len(), 5);
    for s in &series {
        assert!(!s.ys.is_empty(), "{} empty", s.label);
        assert!(s.ys.iter().all(|y| y.is_finite()), "{} NaN", s.label);
    }
    // At least the EC s=2 run should improve over its start.
    let ec2 = &series[2];
    assert!(ec2.last_y() < ec2.ys[0] * 1.05, "{:?}", ec2.ys);
}

#[test]
fn sec5_fast_run_is_sane() {
    let r = easgd_cmp::run(Scale::Fast, 4);
    for s in &r.series {
        assert!(s.last_y() < s.ys[0], "{} did not descend", s.label);
    }
}

#[test]
fn config_to_run_roundtrip_gaussian() {
    let cfg = RunConfig::from_toml_str(
        "[run]\nscheme = \"ec\"\ntarget = \"gaussian\"\nsteps = 300\n[coordinator]\nworkers = 2\n",
    )
    .unwrap();
    let r = ecsgmcmc::cli::commands::run_configured(&cfg).unwrap();
    assert_eq!(r.chains.len(), 2);
    assert!(r.metrics.steps_per_sec > 0.0);
}
