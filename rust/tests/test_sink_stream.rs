//! End-to-end stream integrity: run → JSONL → replay must preserve the
//! samples (bit-exactly) and the moments; online diagnostics computed
//! while sampling must match the post-hoc whole-trace estimators; the
//! memory cap must report, not silently truncate.

use ecsgmcmc::coordinator::{EcConfig, EcCoordinator, RunOptions, RunResult};
use ecsgmcmc::diagnostics::{ess, moments, rhat, to_f64_samples};
use ecsgmcmc::potentials::gaussian::GaussianPotential;
use ecsgmcmc::samplers::SghmcParams;
use ecsgmcmc::sink::{replay, SinkSpec};
use std::path::PathBuf;
use std::sync::Arc;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ecsgmcmc-stream-{name}-{}.jsonl", std::process::id()))
}

fn ec_run(sink: SinkSpec, opts_base: RunOptions, steps: usize, seed: u64) -> RunResult {
    let cfg = EcConfig {
        workers: 4,
        alpha: 1.0,
        sync_every: 2,
        steps,
        opts: RunOptions { sink, ..opts_base },
        ..Default::default()
    };
    EcCoordinator::new(
        cfg,
        SghmcParams { eps: 0.05, ..Default::default() },
        Arc::new(GaussianPotential::fig1()),
    )
    .run(seed)
}

#[test]
fn jsonl_stream_replays_bit_identical_samples() {
    let path = tmp("roundtrip");
    let tee = SinkSpec::Tee(vec![SinkSpec::Memory, SinkSpec::Jsonl { path: path.clone() }]);
    let opts = RunOptions { thin: 2, burn_in: 100, log_every: 50, ..Default::default() };
    let live = ec_run(tee, opts, 1_000, 7);
    let replayed = replay::replay_file(&path).unwrap();

    assert_eq!(replayed.chains.len(), live.chains.len());
    for (a, b) in live.chains.iter().zip(&replayed.chains) {
        assert_eq!(a.worker, b.worker);
        assert_eq!(a.samples.len(), b.samples.len(), "chain {}", a.worker);
        for ((ta, va), (tb, vb)) in a.samples.iter().zip(&b.samples) {
            assert_eq!(ta, tb, "timestamp round trip");
            assert_eq!(va, vb, "theta round trip");
        }
        assert_eq!(a.u_trace.len(), b.u_trace.len());
        for (ua, ub) in a.u_trace.iter().zip(&b.u_trace) {
            assert_eq!(ua.step, ub.step);
            assert_eq!(ua.u, ub.u);
        }
    }
    assert_eq!(live.center_trace, replayed.center_trace);
    assert_eq!(live.samples.len(), replayed.samples.len());
    assert_eq!(replayed.metrics.exchanges, live.metrics.exchanges);
    assert_eq!(replayed.metrics.total_steps, live.metrics.total_steps);
    assert_eq!(replayed.metrics.center_steps, live.metrics.center_steps);

    // The acceptance criterion: replayed moments within 1e-6 (they are
    // in fact bit-identical, since every number round-trips exactly).
    let live_m = moments(&to_f64_samples(live.thetas(), 2));
    let rep_m = moments(&to_f64_samples(replayed.thetas(), 2));
    for (a, b) in live_m.mean.iter().zip(&rep_m.mean) {
        assert!((a - b).abs() < 1e-6, "mean {a} vs {b}");
    }
    for (a, b) in live_m.cov.iter().zip(&rep_m.cov) {
        assert!((a - b).abs() < 1e-6, "cov {a} vs {b}");
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn pure_jsonl_streams_past_max_samples_without_truncation() {
    let path = tmp("unbounded");
    // A tiny in-memory cap that the run's output far exceeds: the old
    // recorder would silently truncate at 50 samples per chain; the
    // stream keeps everything and memory holds no samples at all.
    let opts = RunOptions { thin: 1, burn_in: 0, max_samples: 50, ..Default::default() };
    let steps = 400;
    let live = ec_run(SinkSpec::Jsonl { path: path.clone() }, opts, steps, 11);
    assert!(live.chains.iter().all(|c| c.samples.is_empty()));
    assert!(live.samples.is_empty());
    assert_eq!(live.metrics.samples_dropped, 0, "streamed, so nothing is lost");

    let replayed = replay::replay_file(&path).unwrap();
    assert_eq!(replayed.samples.len(), 4 * steps, "every sample is on disk");
    for c in &replayed.chains {
        assert_eq!(c.samples.len(), steps);
        assert!(c.samples.iter().all(|(_, th)| th.iter().all(|x| x.is_finite())));
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn memory_cap_reports_dropped_instead_of_silent_truncation() {
    let opts = RunOptions { thin: 1, burn_in: 0, max_samples: 50, ..Default::default() };
    let r = ec_run(SinkSpec::Memory, opts, 400, 11);
    for c in &r.chains {
        assert_eq!(c.samples.len(), 50);
        assert_eq!(c.dropped, 350);
    }
    assert_eq!(r.metrics.samples_dropped, 4 * 350);
}

#[test]
fn online_diag_matches_posthoc_diagnostics() {
    // The Fig. 1 Gaussian config: pooled moments, split-R̂ and ESS from
    // the online sink must equal the post-hoc diagnostics over the
    // retained traces (exactly, while no batch collapse happened).
    let opts = RunOptions { thin: 2, burn_in: 400, log_every: 1_000, ..Default::default() };
    let r = ec_run(SinkSpec::Tee(vec![SinkSpec::Memory, SinkSpec::OnlineDiag]), opts, 4_000, 17);
    let d = r.online_diag.as_ref().expect("online diag attached");
    assert_eq!(d.batch, 1, "no batch collapse at this run length");
    assert_eq!(d.chains, 4);
    assert_eq!(d.tracked, 2);
    let n_per_chain = r.chains[0].samples.len();
    assert_eq!(d.n as usize, 4 * n_per_chain);

    let per_chain: Vec<Vec<Vec<f64>>> = r
        .chains
        .iter()
        .map(|c| to_f64_samples(c.samples.iter().map(|(_, th)| th.as_slice()), 2))
        .collect();

    let posthoc_rhat = rhat::max_rhat(&per_chain);
    assert!(
        (d.max_rhat - posthoc_rhat).abs() < 1e-6,
        "online R-hat {} vs post-hoc {posthoc_rhat}",
        d.max_rhat
    );

    let posthoc_min_ess = (0..2)
        .map(|j| {
            per_chain
                .iter()
                .map(|c| ess::ess(&c.iter().map(|s| s[j]).collect::<Vec<_>>()))
                .sum::<f64>()
        })
        .fold(f64::INFINITY, f64::min);
    assert!(
        (d.min_ess - posthoc_min_ess).abs() < 1e-6,
        "online ESS {} vs post-hoc {posthoc_min_ess}",
        d.min_ess
    );

    let m = moments(&to_f64_samples(r.thetas(), 2));
    for j in 0..2 {
        assert!((d.mean[j] - m.mean[j]).abs() < 1e-6, "mean[{j}]");
    }
    for i in 0..4 {
        assert!((d.cov[i] - m.cov[i]).abs() < 1e-6, "cov[{i}]");
    }
    // Sanity: the Fig. 1 chains actually converged by these measures.
    assert!(d.max_rhat < 1.2, "R-hat {}", d.max_rhat);
    assert!(d.min_ess > 50.0, "ESS {}", d.min_ess);
}

#[test]
fn memory_sink_is_bit_compatible_with_default_path() {
    // SinkSpec::Memory (explicit) and the default RunOptions must give
    // identical trajectories — the sink layer adds no observable change.
    let opts = RunOptions { thin: 1, ..Default::default() };
    let a = ec_run(SinkSpec::Memory, opts.clone(), 300, 23);
    let b = ec_run(SinkSpec::Memory, opts, 300, 23);
    for (ca, cb) in a.chains.iter().zip(&b.chains) {
        assert_eq!(ca.samples, cb.samples);
    }
}

#[test]
fn stream_diag_agrees_with_replay_then_posthoc() {
    let path = tmp("streamdiag");
    let opts = RunOptions { thin: 2, burn_in: 200, log_every: 500, ..Default::default() };
    ec_run(SinkSpec::Jsonl { path: path.clone() }, opts, 2_000, 29);

    // Bounded-memory path: fold the stream straight into diagnostics.
    let file = std::fs::File::open(&path).unwrap();
    let (d, metrics) = replay::stream_diag(file).unwrap();
    assert!(metrics.is_some());

    // Reconstruction path: replay, then post-hoc diagnostics.
    let replayed = replay::replay_file(&path).unwrap();
    let per_chain: Vec<Vec<Vec<f64>>> = replayed
        .chains
        .iter()
        .map(|c| to_f64_samples(c.samples.iter().map(|(_, th)| th.as_slice()), 2))
        .collect();
    let posthoc = rhat::max_rhat(&per_chain);
    assert!((d.max_rhat - posthoc).abs() < 1e-6, "{} vs {posthoc}", d.max_rhat);
    std::fs::remove_file(&path).ok();
}
