//! Telemetry end-to-end: enabling span tracing must not perturb the
//! sampled trajectories (the disabled/enabled paths never touch sampler
//! state), streamed telemetry frames must stay schema-additive for
//! replay, and the export surfaces (Chrome trace, `top`) must reflect
//! the run.

use ecsgmcmc::coordinator::{EcConfig, EcCoordinator, RunOptions, RunResult};
use ecsgmcmc::potentials::gaussian::GaussianPotential;
use ecsgmcmc::samplers::SghmcParams;
use ecsgmcmc::sink::{replay, SinkSpec};
use ecsgmcmc::telemetry;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// The telemetry switches are process-global; every test that flips
/// them runs under this lock and restores "off" on exit.
static LOCK: Mutex<()> = Mutex::new(());

struct TelemetryOff;
impl Drop for TelemetryOff {
    fn drop(&mut self) {
        telemetry::set_enabled(false);
    }
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ecsgmcmc-telemetry-{name}-{}.jsonl", std::process::id()))
}

fn ec_run(sink: SinkSpec, steps: usize, seed: u64) -> RunResult {
    let cfg = EcConfig {
        workers: 4,
        alpha: 1.0,
        sync_every: 2,
        steps,
        opts: RunOptions {
            thin: 2,
            burn_in: 50,
            log_every: 100,
            sink,
            ..Default::default()
        },
        ..Default::default()
    };
    EcCoordinator::new(
        cfg,
        SghmcParams { eps: 0.05, ..Default::default() },
        Arc::new(GaussianPotential::fig1()),
    )
    .run(seed)
}

fn assert_same_trajectories(a: &RunResult, b: &RunResult) {
    assert_eq!(a.chains.len(), b.chains.len());
    for (ca, cb) in a.chains.iter().zip(&b.chains) {
        assert_eq!(ca.worker, cb.worker);
        assert_eq!(ca.samples, cb.samples, "chain {} samples", ca.worker);
        assert_eq!(ca.u_trace.len(), cb.u_trace.len(), "chain {} u trace", ca.worker);
        for (ua, ub) in ca.u_trace.iter().zip(&cb.u_trace) {
            assert_eq!(ua.step, ub.step);
            assert_eq!(ua.u, ub.u);
        }
    }
    assert_eq!(a.center_trace, b.center_trace);
    assert_eq!(a.metrics.exchanges, b.metrics.exchanges);
    assert_eq!(a.metrics.total_steps, b.metrics.total_steps);
}

#[test]
fn fig1_run_is_bit_identical_with_telemetry_on() {
    let _guard = LOCK.lock().unwrap();
    let _restore = TelemetryOff;
    telemetry::set_enabled(false);
    let off = ec_run(SinkSpec::Memory, 600, 7);
    assert!(off.metrics.stage_totals.is_empty(), "no totals when disabled");

    telemetry::configure(true, 5, 1024);
    let on = ec_run(SinkSpec::Memory, 600, 7);
    telemetry::set_enabled(false);

    assert_same_trajectories(&off, &on);
    // The enabled run folded real span totals into its run summary.
    let grad = on
        .metrics
        .stage_totals
        .iter()
        .find(|(s, _, _)| s == "stoch_grad")
        .expect("stoch_grad stage total");
    assert!(grad.1 > 0 && grad.2 > 0, "count/ns populated: {grad:?}");
    assert!(on.metrics.stage_totals.iter().any(|(s, _, _)| s == "exchange"));
}

#[test]
fn stream_with_telemetry_frames_replays_identically_and_additively() {
    let _guard = LOCK.lock().unwrap();
    let _restore = TelemetryOff;
    telemetry::set_enabled(false);
    let path_off = tmp("off");
    let path_on = tmp("on");

    ec_run(SinkSpec::Jsonl { path: path_off.clone() }, 400, 11);
    telemetry::configure(true, 3, 1024);
    ec_run(SinkSpec::Jsonl { path: path_on.clone() }, 400, 11);
    telemetry::set_enabled(false);

    // Replay must ignore the telemetry annotations: both streams
    // reconstruct the same run.
    let off = replay::replay_file(&path_off).unwrap();
    let on = replay::replay_file(&path_on).unwrap();
    assert_same_trajectories(&off, &on);

    // The enabled stream actually carries frames, with per-stage
    // quantiles and thread labels, and its metrics event round-trips
    // the stage totals (stream v3, schema-additive).
    let mut frames = 0usize;
    let mut saw_worker_label = false;
    let file = std::fs::File::open(&path_on).unwrap();
    replay::scan_stream(file, |ev| {
        if let replay::RunEvent::Telemetry { json, .. } = ev {
            frames += 1;
            let stages = json.get("stages").expect("stages object");
            if let Some(grad) = stages.get("stoch_grad") {
                assert!(grad.get("p50_ns").is_some(), "quantiles present");
            }
            let threads = format!("{json:?}");
            saw_worker_label |= threads.contains("ec-worker");
        }
        Ok(())
    })
    .unwrap();
    assert!(frames > 0, "enabled stream carries telemetry frames");
    assert!(saw_worker_label, "thread labels name the EC workers");
    assert!(!on.metrics.stage_totals.is_empty(), "metrics event carries stage totals");
    assert!(off.metrics.stage_totals.is_empty());

    std::fs::remove_file(&path_off).ok();
    std::fs::remove_file(&path_on).ok();
}

#[test]
fn trace_export_and_top_render_from_a_real_stream() {
    let _guard = LOCK.lock().unwrap();
    let _restore = TelemetryOff;
    let stream = tmp("export");
    let trace = std::env::temp_dir()
        .join(format!("ecsgmcmc-telemetry-trace-{}.json", std::process::id()));

    telemetry::configure(true, 2, 2048);
    ec_run(SinkSpec::Jsonl { path: stream.clone() }, 400, 13);
    telemetry::set_enabled(false);

    let stats = telemetry::chrome::write_trace(&stream, &trace).unwrap();
    assert!(stats.telemetry_events > 0);
    assert!(stats.spans > 0, "trace carries span slices");
    assert!(stats.threads > 0, "trace names at least one thread");
    let text = std::fs::read_to_string(&trace).unwrap();
    assert!(text.contains("\"traceEvents\""), "Chrome trace envelope");
    assert!(text.contains("stoch_grad"));

    let rendered = telemetry::top::top_once(&stream).unwrap();
    assert!(rendered.contains("stoch_grad"), "top lists the gradient stage:\n{rendered}");
    assert!(rendered.contains("p95"), "top shows quantile columns:\n{rendered}");

    std::fs::remove_file(&stream).ok();
    std::fs::remove_file(&trace).ok();
}

#[test]
fn spans_nest_within_and_across_threads() {
    let _guard = LOCK.lock().unwrap();
    let _restore = TelemetryOff;
    telemetry::configure(true, 1, 256);
    telemetry::discard_pending();

    // Worker thread: an Exchange span enclosing a Gemm span.
    std::thread::Builder::new()
        .name("tel-worker".into())
        .spawn(|| {
            let _outer = telemetry::span(telemetry::Stage::Exchange);
            std::thread::sleep(std::time::Duration::from_millis(2));
            let _inner = telemetry::span(telemetry::Stage::Gemm);
            std::thread::sleep(std::time::Duration::from_millis(1));
        })
        .unwrap()
        .join()
        .unwrap();
    // Coordinator (this) thread: an unrelated span.
    {
        let _s = telemetry::span(telemetry::Stage::SinkFlush);
    }
    telemetry::set_enabled(false);

    let mut agg = telemetry::Aggregate::default();
    telemetry::drain_into(&mut agg);
    let (spans, _) = agg.take_recent();
    let find = |stage: telemetry::Stage| {
        spans
            .iter()
            .find(|s| s.stage == stage as u8)
            .unwrap_or_else(|| panic!("missing {stage:?} span"))
    };
    let outer = find(telemetry::Stage::Exchange);
    let inner = find(telemetry::Stage::Gemm);
    let flush = find(telemetry::Stage::SinkFlush);

    assert_eq!(outer.tid, inner.tid, "nested spans share a thread");
    assert_ne!(outer.tid, flush.tid, "other thread gets its own id");
    assert!(inner.t_start_ns >= outer.t_start_ns, "inner starts inside outer");
    assert!(
        inner.t_start_ns + inner.dur_ns <= outer.t_start_ns + outer.dur_ns,
        "inner ends before outer"
    );
    assert!(outer.dur_ns >= 3_000_000, "outer covers both sleeps");

    let labels = telemetry::thread_labels();
    assert!(
        labels.iter().any(|(tid, name)| *tid == outer.tid && name == "tel-worker"),
        "thread label registered: {labels:?}"
    );
}

#[test]
fn disabled_runtime_records_nothing() {
    let _guard = LOCK.lock().unwrap();
    let _restore = TelemetryOff;
    telemetry::set_enabled(false);
    telemetry::discard_pending();
    {
        let _s = telemetry::span(telemetry::Stage::StochGrad);
        let _t = telemetry::span_arg(telemetry::Stage::Gemm, 123);
    }
    let mut agg = telemetry::Aggregate::default();
    telemetry::drain_into(&mut agg);
    assert_eq!(agg.total_spans(), 0, "disabled spans are inert");
}
