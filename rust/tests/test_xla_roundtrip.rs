//! Integration tests of the full AOT round-trip: python-lowered HLO
//! artifacts loaded and executed through PJRT from Rust, cross-checked
//! against analytic values and the native-Rust oracles.
//!
//! Requires `make artifacts` (skips gracefully with a visible marker when
//! artifacts are absent, so `cargo test` stays green pre-AOT).

use ecsgmcmc::data::{synth_cifar, synth_mnist};
use ecsgmcmc::math::rng::Pcg64;
use ecsgmcmc::potentials::nn::mlp::NativeMlp;
use ecsgmcmc::potentials::nn::resnet::NativeResNet;
use ecsgmcmc::potentials::xla::{pack_scal, XlaFusedSampler, XlaPotential};
use ecsgmcmc::potentials::Potential;
use ecsgmcmc::runtime::{Arg, Engine};
use ecsgmcmc::samplers::sghmc::SghmcStepper;
use ecsgmcmc::samplers::{ChainState, SghmcParams};

fn engine() -> Option<Engine> {
    match Engine::new(Engine::default_dir()) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIPPED (no artifacts: {err}) — run `make artifacts`");
            None
        }
    }
}

#[test]
fn gaussian_grad_artifact_matches_analytic() {
    let Some(engine) = engine() else { return };
    let art = engine.load("gaussian_grad").unwrap();
    let theta = [0.7f32, -1.2];
    let outs = art.run(&[Arg::F32(&theta)]).unwrap();
    let (u, grad) = (&outs[0], &outs[1]);
    // Precision of [[1,.6],[.6,.8]] = 1/0.44 [[.8,-.6],[-.6,1]].
    let det = 0.44f64;
    let want0 = (0.8 * 0.7 + 0.6 * 1.2) / det;
    let want1 = (-0.6 * 0.7 - 1.2) / det;
    assert!((grad[0] as f64 - want0).abs() < 1e-4, "g0={} want {want0}", grad[0]);
    assert!((grad[1] as f64 - want1).abs() < 1e-4, "g1={} want {want1}", grad[1]);
    let want_u = 0.5 * (0.7 * want0 - 1.2 * want1);
    assert!((u[0] as f64 - want_u).abs() < 1e-4);
}

#[test]
fn sghmc_step_artifact_matches_native_stepper() {
    let Some(engine) = engine() else { return };
    let art = engine.load("sghmc_step_mlp").unwrap();
    let n = art.spec.meta_usize("padded_n").unwrap();
    let mut rng = Pcg64::seeded(5);
    let mut theta = vec![0.0f32; n];
    let mut p = vec![0.0f32; n];
    let mut grad = vec![0.0f32; n];
    let mut noise = vec![0.0f32; n];
    rng.fill_normal(&mut theta);
    rng.fill_normal(&mut p);
    rng.fill_normal(&mut grad);
    rng.fill_normal(&mut noise);

    let params = SghmcParams { eps: 0.01, ..Default::default() };
    let scal = pack_scal(params.eps, 1.0, 1.0, 0.0, params.sghmc_noise_scale());
    let outs = art
        .run(&[Arg::F32(&scal), Arg::F32(&theta), Arg::F32(&p), Arg::F32(&grad), Arg::F32(&noise)])
        .unwrap();

    // Native step with the identical precomputed noise: replicate the
    // formula directly (the stepper draws its own noise, so compare math).
    let eps = 0.01f32;
    let nscale = params.sghmc_noise_scale() as f32;
    for i in 0..n {
        let want_theta = theta[i] + eps * p[i];
        let want_p = p[i] - eps * grad[i] - eps * p[i] + nscale * noise[i];
        assert!((outs[0][i] - want_theta).abs() < 1e-5, "i={i}");
        assert!((outs[1][i] - want_p).abs() < 1e-5, "i={i}");
    }
}

#[test]
fn ec_step_artifact_applies_elastic_force() {
    let Some(engine) = engine() else { return };
    let art = engine.load("ec_step_mlp").unwrap();
    let n = art.spec.meta_usize("padded_n").unwrap();
    let theta = vec![1.0f32; n];
    let p = vec![0.0f32; n];
    let grad = vec![0.0f32; n];
    let center = vec![0.0f32; n];
    let noise = vec![0.0f32; n];
    let alpha = 2.0;
    let scal = pack_scal(0.01, 1.0, 0.0, alpha, 0.0);
    let outs = art
        .run(&[
            Arg::F32(&scal),
            Arg::F32(&theta),
            Arg::F32(&p),
            Arg::F32(&grad),
            Arg::F32(&center),
            Arg::F32(&noise),
        ])
        .unwrap();
    // p' = -eps * alpha * (theta - c) = -0.02
    for i in 0..n {
        assert!((outs[1][i] + 0.02).abs() < 1e-6, "p'[{i}]={}", outs[1][i]);
        assert!((outs[0][i] - 1.0).abs() < 1e-6); // theta' = theta (p was 0)
    }
}

#[test]
fn mlp_grad_artifact_matches_native_oracle() {
    let Some(engine) = engine() else { return };
    let art = engine.load("mlp_grad").unwrap();
    let batch = art.spec.meta_usize("batch").unwrap();
    let hidden = art.spec.meta_usize("hidden").unwrap();
    let n_total = art.spec.meta_usize("n_total").unwrap();
    let n_params = art.spec.meta_usize("n_params").unwrap();
    let padded = art.spec.meta_usize("padded_n").unwrap();

    // Same data, same theta for both paths.
    let data = synth_mnist::generate(n_total, 0.15, 99);
    let native = NativeMlp::new(data.clone(), data.clone(), hidden, 2, batch);
    assert_eq!(native.n_params(), n_params, "architectures diverged");

    let mut rng = Pcg64::seeded(6);
    let theta = native.init_theta(0.1, &mut rng);
    let mut x = vec![0.0f32; batch * data.d];
    let mut y = vec![0i32; batch];
    data.sample_batch(batch, &mut rng, &mut x, &mut y);

    let outs = art.run(&[Arg::F32(&theta), Arg::F32(&x), Arg::I32(&y)]).unwrap();
    let (u_xla, g_xla) = (outs[0][0] as f64, &outs[1]);

    // Native gradient on the same batch via grad_on_batch equivalent:
    // reconstruct by calling logits + manual loss is private; instead use
    // the scaled potential identity with a single-batch dataset.
    let single = ecsgmcmc::data::Dataset::new(x.clone(), y.clone(), data.d, data.classes);
    let native_single = NativeMlp::new(single, data.clone(), hidden, 2, batch);
    // full_grad over exactly this batch computes sum nll + prior; the
    // artifact computes (N/B) sum nll + prior. Compare after rescaling the
    // likelihood part.
    let mut g_full = vec![0.0f32; padded];
    let u_full = native_single.full_grad(&theta, &mut g_full);
    let scale = n_total as f64 / batch as f64;
    // prior term
    let wd = 1e-5f64;
    let prior: f64 = theta[..n_params].iter().map(|&t| (t as f64) * (t as f64)).sum::<f64>() * wd;
    let u_native_scaled = (u_full - prior) * scale + prior;
    assert!(
        (u_xla - u_native_scaled).abs() / u_native_scaled.abs() < 1e-3,
        "u_xla={u_xla} u_native={u_native_scaled}"
    );
    // Gradient cosine after the same rescaling.
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for i in 0..n_params {
        let gn = (g_full[i] as f64 - 2.0 * wd * theta[i] as f64) * scale
            + 2.0 * wd * theta[i] as f64;
        let gx = g_xla[i] as f64;
        dot += gn * gx;
        na += gn * gn;
        nb += gx * gx;
    }
    let cos = dot / (na.sqrt() * nb.sqrt());
    assert!(cos > 0.9999, "cosine={cos}");
}

#[test]
fn fused_update_equals_grad_plus_step() {
    let Some(engine) = engine() else { return };
    let grad_art = engine.load("mlp_grad").unwrap();
    let fused = engine.load("mlp_ec_update").unwrap();
    let n = fused.spec.meta_usize("padded_n").unwrap();
    let batch = fused.spec.meta_usize("batch").unwrap();
    let in_dim = fused.spec.inputs[4].shape[1];

    let mut rng = Pcg64::seeded(7);
    let mut theta = vec![0.0f32; n];
    rng.fill_normal(&mut theta);
    for t in theta.iter_mut() {
        *t *= 0.05;
    }
    let mut p = vec![0.0f32; n];
    let mut c = vec![0.0f32; n];
    let mut noise = vec![0.0f32; n];
    rng.fill_normal(&mut p);
    rng.fill_normal(&mut c);
    rng.fill_normal(&mut noise);
    let mut x = vec![0.0f32; batch * in_dim];
    rng.fill_normal(&mut x);
    let y: Vec<i32> = (0..batch).map(|i| (i % 10) as i32).collect();

    let params = SghmcParams { eps: 1e-4, ..Default::default() };
    let alpha = 0.7;
    let scal = pack_scal(params.eps, 1.0, 1.0, alpha, params.ec_worker_noise_scale());

    // Path A: fused artifact.
    let outs = fused
        .run(&[
            Arg::F32(&scal),
            Arg::F32(&theta),
            Arg::F32(&p),
            Arg::F32(&c),
            Arg::F32(&x),
            Arg::I32(&y),
            Arg::F32(&noise),
        ])
        .unwrap();

    // Path B: grad artifact + native Eq. 6 math with the same noise.
    let gouts = grad_art.run(&[Arg::F32(&theta), Arg::F32(&x), Arg::I32(&y)]).unwrap();
    let g = &gouts[1];
    let eps = params.eps as f32;
    let nscale = params.ec_worker_noise_scale() as f32;
    for i in (0..n).step_by(97) {
        let want_theta = theta[i] + eps * p[i];
        let want_p = p[i] - eps * g[i] - eps * p[i] - eps * (alpha as f32) * (theta[i] - c[i])
            + nscale * noise[i];
        assert!((outs[0][i] - want_theta).abs() < 1e-5, "theta[{i}]");
        let tol = 1e-4 + want_p.abs() * 1e-4;
        assert!((outs[1][i] - want_p).abs() < tol, "p[{i}]: {} vs {want_p}", outs[1][i]);
    }
    // U values agree.
    assert!((outs[2][0] - gouts[0][0]).abs() / gouts[0][0].abs() < 1e-4);
}

#[test]
fn fused_sampler_reduces_potential_over_steps() {
    let Some(engine) = engine() else { return };
    let spec = engine.manifest.artifacts.get("mlp_grad").unwrap();
    let n_total = spec.meta_usize("n_total").unwrap().min(2048);
    let train = synth_mnist::generate(n_total, 0.15, 31);
    let params = SghmcParams { eps: 1e-4, ..Default::default() };
    let mut sampler = XlaFusedSampler::new(&engine, "mlp", train, params).unwrap();
    let mut rng = Pcg64::seeded(8);
    let mut state = ChainState::zeros(sampler.padded);
    rng.fill_normal(&mut state.theta[..sampler.live]);
    for t in state.theta[..sampler.live].iter_mut() {
        *t *= 0.1;
    }
    let mut us = Vec::new();
    for _ in 0..30 {
        us.push(sampler.sghmc_step(&mut state, &mut rng).unwrap());
    }
    let head: f64 = us[..5].iter().sum::<f64>() / 5.0;
    let tail: f64 = us[us.len() - 5..].iter().sum::<f64>() / 5.0;
    assert!(tail < head, "potential did not decrease: {head} -> {tail}");
}

#[test]
fn resnet_grad_artifact_matches_native_shapes_and_descends() {
    let Some(engine) = engine() else { return };
    let art = engine.load("resnet_grad").unwrap();
    let batch = art.spec.meta_usize("batch").unwrap();
    let width = art.spec.meta_usize("width").unwrap();
    let blocks = art.spec.meta_usize("blocks").unwrap();
    let n_params = art.spec.meta_usize("n_params").unwrap();
    let data = synth_cifar::generate(batch.max(64), 0.2, 12);
    let native = NativeResNet::new(data.clone(), data.clone(), width, blocks, batch);
    assert_eq!(native.n_params(), n_params, "resnet architectures diverged");

    // One gradient-descent step on the artifact gradient lowers U.
    let mut rng = Pcg64::seeded(13);
    let theta = native.init_theta(0.05, &mut rng);
    let mut x = vec![0.0f32; batch * data.d];
    let mut y = vec![0i32; batch];
    data.sample_batch(batch, &mut rng, &mut x, &mut y);
    let outs = art.run(&[Arg::F32(&theta), Arg::F32(&x), Arg::I32(&y)]).unwrap();
    let u0 = outs[0][0];
    let mut theta2 = theta.clone();
    for i in 0..theta2.len() {
        theta2[i] -= 1e-6 * outs[1][i];
    }
    let outs2 = art.run(&[Arg::F32(&theta2), Arg::F32(&x), Arg::I32(&y)]).unwrap();
    assert!(outs2[0][0] < u0, "descent failed: {u0} -> {}", outs2[0][0]);
}

#[test]
fn xla_potential_eval_and_dims_consistent() {
    let Some(engine) = engine() else { return };
    let spec = engine.manifest.artifacts.get("mlp_grad").unwrap();
    let n_total = spec.meta_usize("n_total").unwrap().min(2048);
    let data = synth_mnist::generate(n_total + 256, 0.15, 55);
    let (train, test) = data.split(n_total);
    let pot = XlaPotential::new(&engine, "mlp", train, test).unwrap();
    assert!(pot.padded_dim() >= pot.dim());
    assert_eq!(pot.padded_dim() % 1024, 0);
    let mut rng = Pcg64::seeded(9);
    let mut theta = vec![0.0f32; pot.padded_dim()];
    rng.fill_normal(&mut theta[..pot.dim()]);
    for t in theta.iter_mut() {
        *t *= 0.05;
    }
    let (nll, acc) = pot.eval_nll_acc(&theta).unwrap();
    assert!(nll.is_finite() && nll > 0.0);
    assert!((0.0..=1.0).contains(&acc));
    let mut grad = vec![0.0f32; pot.padded_dim()];
    let u = pot.stoch_grad(&theta, &mut grad, &mut rng);
    assert!(u.is_finite());
    // Padding tail must be exactly zero.
    assert!(grad[pot.dim()..].iter().all(|&g| g == 0.0));
}

#[test]
fn center_update_artifact_matches_native_center_step() {
    let Some(engine) = engine() else { return };
    let art = engine.load("center_update_mlp").unwrap();
    let n = art.spec.meta_usize("padded_n").unwrap();
    let mut rng = Pcg64::seeded(14);
    let mut c = vec![0.0f32; n];
    let mut r = vec![0.0f32; n];
    let mut mean = vec![0.0f32; n];
    let mut noise = vec![0.0f32; n];
    rng.fill_normal(&mut c);
    rng.fill_normal(&mut r);
    rng.fill_normal(&mut mean);
    rng.fill_normal(&mut noise);
    let params = SghmcParams { eps: 0.01, ..Default::default() };
    let alpha = 1.5;
    let scal = pack_scal(0.01, 1.0, 1.0, alpha, params.center_noise_scale());
    let outs = art
        .run(&[Arg::F32(&scal), Arg::F32(&c), Arg::F32(&r), Arg::F32(&mean), Arg::F32(&noise)])
        .unwrap();
    let eps = 0.01f32;
    let ns = params.center_noise_scale() as f32;
    for i in (0..n).step_by(53) {
        let want_c = c[i] + eps * r[i];
        let want_r = r[i] - eps * r[i] - eps * (alpha as f32) * (c[i] - mean[i]) + ns * noise[i];
        assert!((outs[0][i] - want_c).abs() < 1e-5);
        assert!((outs[1][i] - want_r).abs() < 1e-5);
    }
    // Cross-check against the Rust CenterStepper formulas via a zero-noise
    // case (the stepper draws internal noise; compare structure only).
    let mut stepper =
        ecsgmcmc::samplers::sghmc::CenterStepper::new(
            SghmcParams { center_friction: 0.0, noise_var: 0.0, ..params },
            alpha,
            4,
        );
    let mut st = ChainState { theta: vec![1.0; 4], p: vec![0.5; 4] };
    let m = vec![0.0f32; 4];
    stepper.step(&mut st, &m, &mut rng);
    assert!((st.theta[0] - (1.0 + 0.01 * 0.5)).abs() < 1e-6);
    let _ = SghmcStepper::new(params, 4); // silence unused-import pattern
}
