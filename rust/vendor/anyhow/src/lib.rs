//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access, so the workspace vendors
//! the exact API subset it uses: [`Error`], [`Result`], the [`Context`]
//! extension trait, and the `anyhow!` / `bail!` macros. Swap this path
//! dependency for the real crates.io `anyhow` at any time — call sites
//! are source-compatible.

use std::fmt;

/// Error value carrying a context chain, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context frames, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, like anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that is what allows the blanket `From` below to
// coexist with the identity `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        // Fold the source chain into context frames up front.
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(|| ...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn context_chains_render_in_order() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .unwrap_err()
            .context("loading experiment");
        assert_eq!(format!("{e}"), "loading experiment");
        assert_eq!(format!("{e:#}"), "loading experiment: reading config: missing file");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn macros_and_result_alias() {
        fn fails(flag: bool) -> Result<u32> {
            if flag {
                bail!("flag was {flag}");
            }
            Err(anyhow!("fell through with {}", 7))
        }
        assert_eq!(format!("{}", fails(true).unwrap_err()), "flag was true");
        assert_eq!(format!("{}", fails(false).unwrap_err()), "fell through with 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn option_context() {
        let v: Result<u8> = None.context("empty");
        assert_eq!(format!("{}", v.unwrap_err()), "empty");
    }
}
